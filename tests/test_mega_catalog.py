"""Mega-catalog serving modes of the fused route step: int8 quantized
scan, IVF two-level pruned search, catalog-sharded cross-device kNN —
plus the padded-constant cache regression.

Recall methodology: at d=8 the cosine gap between neighboring catalog
entries sits below int8 resolution, so quantized recall is scored
against the quantization error bound — a retrieved candidate whose
EXACT score is within ``_eps_tol`` of the exact k-th best is a hit
(same metric as ``benchmarks/router_scale.bench_mega``).  IVF recall
(a pruning property, not a precision one) is scored exact-set.
"""
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mres import build_ivf
from repro.core.preferences import DOMAINS, METRICS, TASK_TYPES
from repro.core.routing import RoutingEngine
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.router_topk import tree_merge_topk
from repro.launch.mesh import make_routing_mesh
from tests.conftest import make_entry
from tests.test_route_step import _random_problem, _ref_kwargs
from tests.test_routing_batch import random_catalog, random_queries

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _eps_tol(m: int) -> float:
    """Worst-case |Δcosine| of symmetric int8 quantization of two unit
    vectors (per-component error <= scale/2, scale <= 1/127)."""
    return float(np.sqrt(m) / 127.0 + m / (2.0 * 127.0 ** 2))


def _eps_recall(got, want, emb, T, tol) -> float:
    """Fraction of retrieved candidates whose exact cosine is within
    ``tol`` of the exact k-th best (stage-0 rows only)."""
    embn = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
    qn = T / (np.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    num = den = 0
    for b in range(T.shape[0]):
        if want["stage"][b] != 0 or got["stage"][b] != 0:
            continue
        rrow = [int(x) for x in want["cand_idx"][b] if x >= 0]
        trow = [int(x) for x in got["cand_idx"][b] if x >= 0]
        if not rrow:
            continue
        ckth = float((embn[rrow] @ qn[b]).min())
        ct = embn[trow] @ qn[b]
        den += len(rrow)
        num += min(len(rrow), int((ct >= ckth - tol).sum()))
    return num / max(den, 1)


def _exact_recall(got, want) -> float:
    num = den = 0
    for trow, rrow in zip(got["cand_idx"], want["cand_idx"]):
        rset = {int(x) for x in rrow if x >= 0}
        tset = {int(x) for x in trow if x >= 0}
        den += len(rset)
        num += len(rset & tset)
    return num / max(den, 1)


def _knn_problem(B, N, seed, *, clustered=False):
    """A mask-free problem (every row passes every filter): pure kNN
    precision stress, no fallback rows."""
    rng = np.random.default_rng(seed)
    M = len(METRICS)
    if clustered:
        # adversarial for quantization AND for IVF cell boundaries:
        # tight families whose members differ by less than the int8
        # step, centered on random directions
        centers = rng.random((24, M))
        emb = np.clip(centers[rng.integers(0, 24, N)]
                      + rng.normal(0.0, 0.02, (N, M)), 0.0, 1.0)
    else:
        emb = rng.random((N, M))
    emb = emb.astype(np.float32)
    tt = np.ones((len(TASK_TYPES) + 1, N), bool)
    dm = np.ones((len(DOMAINS) + 1, N), bool)
    gmask = np.ones(N, bool)
    T = rng.random((B, M)).astype(np.float32)
    W = rng.random((B, M)).astype(np.float32)
    ti = np.full(B, len(TASK_TYPES), np.int32)
    di = np.full(B, len(DOMAINS), np.int32)
    return emb, tt, dm, gmask, T, W, ti, di


# ----------------------------------------------------------------------
# int8 quantized scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,N,k", [(5, 64, 4), (9, 200, 8)])
def test_quant_route_step_matches_ref_exact(B, N, k):
    """Without extras the quantized blend is integer dot products plus
    one f32 rescale — the fused program and the jnp oracle agree
    BITWISE on every decision output."""
    args, _ = _random_problem(B, N, seed=B * 100 + N, with_fb=False,
                              with_ad=False, with_load=False)
    r = min(max(5, k), N)
    got = K.route_step(*args, k=k, r=r, quant=True)
    want = R.route_step(*(jnp.asarray(a) for a in args), k, r, quant=True)
    for key in ("model_idx", "stage", "cand_idx", "n_filtered",
                "n_candidates"):
        np.testing.assert_array_equal(got[key], np.asarray(want[key]),
                                      err_msg=key)
    for key in ("score", "similarity", "cand_score"):
        np.testing.assert_allclose(got[key], np.asarray(want[key]),
                                   rtol=2e-5, atol=2e-5, err_msg=key)


def test_quant_route_step_matches_ref_with_extras():
    """With feedback/bandit/load the fused path associates the f32
    extras differently than the oracle (gather-then-add vs
    add-then-gather) — scores agree to fp tolerance."""
    args, kw = _random_problem(7, 150, seed=42)
    got = K.route_step(*args, k=6, r=6, quant=True, **kw)
    want = R.route_step(*(jnp.asarray(a) for a in args), 6, 6,
                        quant=True, **_ref_kwargs(kw))
    np.testing.assert_array_equal(got["stage"], np.asarray(want["stage"]))
    np.testing.assert_allclose(got["cand_score"],
                               np.asarray(want["cand_score"]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("clustered", [False, True])
def test_quant_recall_within_quantization_tolerance(clustered):
    B, N, k = 16, 512, 8
    args = _knn_problem(B, N, seed=11, clustered=clustered)
    fp = K.route_step(*args, k=k, r=k)
    q8 = K.route_step(*args, k=k, r=k, quant=True)
    np.testing.assert_array_equal(fp["stage"], q8["stage"])
    rec = _eps_recall(q8, fp, args[0], args[4], _eps_tol(len(METRICS)))
    assert rec >= 0.99, f"int8 recall {rec} (clustered={clustered})"


def test_quant_pallas_path_matches_jnp():
    """use_pallas=True routes the quantized kNN through the int8
    Pallas kernel (interpret mode) — decision-identical to the jnp
    quantized path (both do exact int32-accumulated dots)."""
    args, kw = _random_problem(9, 140, seed=8)
    got_j = K.route_step(*args, k=5, r=5, quant=True, use_pallas=False,
                         **kw)
    got_p = K.route_step(*args, k=5, r=5, quant=True, use_pallas=True,
                         **kw)
    np.testing.assert_array_equal(got_j["model_idx"], got_p["model_idx"])
    np.testing.assert_array_equal(got_j["stage"], got_p["stage"])
    np.testing.assert_allclose(got_j["score"], got_p["score"],
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# IVF two-level pruned search
# ----------------------------------------------------------------------

def test_ivf_recall_sweep_vs_exhaustive():
    """Exact-set recall vs the exhaustive scan is monotone in
    ``nprobe`` (probed cell sets are nested) and reaches 1.0 at
    nprobe = n_cells, where the pruned program IS exhaustive."""
    B, N, C, k = 8, 1024, 32, 8
    args = _knn_problem(B, N, seed=21, clustered=True)
    ivf = build_ivf(args[0], C)
    dense = K.route_step(*args, k=k, r=k)
    recalls, eps_recalls = [], []
    for nprobe in (1, 2, 4, 8, 16, C):
        got = K.route_step(*args, k=k, r=k, ivf=ivf.as_tuple(),
                           nprobe=nprobe)
        recalls.append(_exact_recall(got, dense))
        eps_recalls.append(_eps_recall(got, dense, args[0], args[4],
                                       _eps_tol(len(METRICS))))
    assert recalls == sorted(recalls), recalls
    assert recalls[-1] == 1.0, recalls
    # the modest default already clears the recall bar on clustered
    # data under the near-tie tolerance (members of a tight family are
    # routing-equivalent; exact-set recall only distinguishes them at
    # wider nprobe, as the sweep above shows)
    assert eps_recalls[3] >= 0.99, (recalls, eps_recalls)


def test_ivf_exhaustive_nprobe_matches_dense_exactly():
    B, N, C, k = 6, 300, 12, 5
    args, kw = _random_problem(B, N, seed=17)
    ivf = build_ivf(args[0], C)
    dense = K.route_step(*args, k=k, r=k, **kw)
    got = K.route_step(*args, k=k, r=k, ivf=ivf.as_tuple(), nprobe=C,
                       **kw)
    for key in ("model_idx", "stage", "cand_idx", "n_filtered",
                "n_candidates"):
        np.testing.assert_array_equal(got[key], dense[key], err_msg=key)
    for key in ("score", "similarity", "cand_score"):
        np.testing.assert_allclose(got[key], dense[key],
                                   rtol=1e-5, atol=1e-5, err_msg=key)


@pytest.mark.parametrize("nprobe", [2, 6])
def test_ivf_matches_ref_oracle(nprobe):
    """The packed-cell device program equals the plain-jnp IVF oracle
    (same probed cells, same fallback ladder) on random masked
    problems — including rows the pruning starves into fallback."""
    B, N, C = 9, 257, 16
    args, kw = _random_problem(B, N, seed=33)
    ivf = build_ivf(args[0], C)
    got = K.route_step(*args, k=6, r=6, ivf=ivf.as_tuple(),
                       nprobe=nprobe, **kw)
    want = R.route_step_ivf(*(jnp.asarray(a) for a in args), 6, 6,
                            jnp.asarray(ivf.centroids),
                            jnp.asarray(ivf.cell_of), nprobe,
                            **_ref_kwargs(kw))
    for key in ("model_idx", "stage", "cand_idx", "n_filtered",
                "n_candidates"):
        np.testing.assert_array_equal(got[key], np.asarray(want[key]),
                                      err_msg=key)
    np.testing.assert_allclose(got["cand_score"],
                               np.asarray(want["cand_score"]),
                               rtol=1e-4, atol=1e-4)


def test_mres_ivf_index_caching():
    """ivf_index() is cached until a registration dirties the store,
    and rebuilds for a different n_cells."""
    mres = random_catalog(40, seed=5)
    a = mres.ivf_index()
    assert a is mres.ivf_index()
    b = mres.ivf_index(n_cells=4)
    assert b is not a and b.n_cells == 4
    mres.register(make_entry("fresh", task_types=("chat",)))
    c = mres.ivf_index()
    assert c is not b
    assert c.cell_of.shape == (41,)


def test_engine_ivf_gating_and_parity():
    """Below ``ivf_min_n`` the engine serves the dense program; at or
    above it the pruned program kicks in and (at default nprobe on a
    small catalog) stays decision-consistent with dense."""
    mres = random_catalog(64, seed=19)
    prefs, sigs = random_queries(6, seed=19)
    dense = RoutingEngine(mres, knn_k=4).route_many_batch(prefs, sigs)
    gated = RoutingEngine(mres, knn_k=4, ivf=True)       # 64 < 4096
    assert gated.route_many_batch(prefs, sigs).models() == dense.models()
    forced = RoutingEngine(mres, knn_k=4, ivf=True, ivf_min_n=1,
                           nprobe=8)
    out = forced.route_many_batch(prefs, sigs)
    assert out.models() == dense.models()
    np.testing.assert_array_equal(out.stage, dense.stage)


# ----------------------------------------------------------------------
# catalog-sharded cross-device program
# ----------------------------------------------------------------------

def test_tree_merge_topk_matches_full_sort():
    """The payload-carrying pairwise merge tree (the cross-shard
    reduction) equals a full sort of the concatenated per-shard
    carries — for power-of-two and odd shard counts — and every
    payload lane rides with its value."""
    rng = np.random.default_rng(3)
    for S in (2, 3, 4, 7):
        Q, k = 4, 5
        vals = -np.sort(-rng.integers(0, 9, (S, Q, k)).astype(np.float32),
                        axis=2)
        idx = np.arange(S * Q * k, dtype=np.int32).reshape(S, Q, k)
        side = rng.random((S, Q, k)).astype(np.float32)
        mv, (mi, ms) = tree_merge_topk(
            jnp.asarray(vals), (jnp.asarray(idx), jnp.asarray(side)))
        flatv = vals.transpose(1, 0, 2).reshape(Q, S * k)
        want = -np.sort(-flatv, axis=1)[:, :k]
        np.testing.assert_array_equal(np.asarray(mv), want, err_msg=f"S={S}")
        flati = idx.transpose(1, 0, 2).reshape(Q, S * k)
        flats = side.transpose(1, 0, 2).reshape(Q, S * k)
        pairs = {(int(i), float(v), float(s))
                 for i, v, s in zip(flati.ravel(), flatv.ravel(),
                                    flats.ravel())}
        for q in range(Q):
            for i, v, s in zip(np.asarray(mi)[q], np.asarray(mv)[q],
                               np.asarray(ms)[q]):
                assert (int(i), float(v), float(s)) in pairs


@needs_devices
@pytest.mark.parametrize("B,N,k,flags", [
    (1, 5, 3, (True, True, True)),       # catalog smaller than mesh
    (9, 130, 8, (True, False, True)),
    (16, 515, 4, (False, True, False)),  # past one sharded bucket
    (33, 96, 2, (False, False, False)),
])
def test_sharded_route_step_bit_identical_to_dense(B, N, k, flags):
    """The acceptance claim: fp32 sharded over 4 devices returns the
    SAME bits as the single-device fused program — every output key,
    including scores."""
    args, kw = _random_problem(B, N, seed=B + N, with_fb=flags[0],
                               with_ad=flags[1], with_load=flags[2])
    r = min(max(5, k), N)
    mesh = make_routing_mesh(4)
    want = K.route_step(*args, k=k, r=r, **kw)
    got = K.route_step(*args, k=k, r=r, mesh=mesh, **kw)
    assert set(got) == set(want)
    for key in sorted(want):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


@needs_devices
def test_sharded_quant_matches_dense_quant():
    args, kw = _random_problem(11, 260, seed=51)
    mesh = make_routing_mesh(4)
    want = K.route_step(*args, k=6, r=6, quant=True, **kw)
    got = K.route_step(*args, k=6, r=6, quant=True, mesh=mesh, **kw)
    for key in ("model_idx", "stage", "cand_idx", "n_filtered",
                "n_candidates"):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)
    for key in ("score", "similarity", "cand_score"):
        np.testing.assert_allclose(got[key], want[key],
                                   rtol=1e-5, atol=1e-5, err_msg=key)


@needs_devices
def test_sharded_engine_parity_and_zero_recompiles():
    """Engine-level: a mesh-attached engine picks identical candidates
    to the default engine, and its steady state keeps the fused
    contract — one dispatch per batch, zero recompiles across mixed
    batch sizes after warmup."""
    mres = random_catalog(96, seed=13)
    eng_d = RoutingEngine(mres, knn_k=8)
    eng_s = RoutingEngine(mres, knn_k=8, mesh=make_routing_mesh(4))
    prefs, sigs = random_queries(9, seed=13)
    d = eng_d.route_many_batch(prefs, sigs)
    s = eng_s.route_many_batch(prefs, sigs)
    assert s.models() == d.models()
    np.testing.assert_array_equal(s.cand_idx, d.cand_idx)
    np.testing.assert_array_equal(s.cand_score, d.cand_score)

    for b in (1, 5, 17):                           # warm the buckets
        eng_s.route_many_batch(*random_queries(b, seed=b))
    warm = K.route_step_stats()
    replay = (3, 9, 1, 12, 17, 6)
    for i, b in enumerate(replay):
        eng_s.route_many_batch(*random_queries(b, seed=50 + i))
    stats = K.route_step_stats()
    assert stats["route_step_compiles"] == warm["route_step_compiles"], \
        "sharded path recompiled after warmup"
    assert stats["route_step_dispatches"] \
        == warm["route_step_dispatches"] + len(replay)


def test_n_bucket_sharded():
    assert [K.n_bucket_sharded(n, 4) for n in (1, 512, 513, 2048)] == \
        [512, 512, 1024, 2048]
    assert K.n_bucket_sharded(100_000, 4) == 100_352
    assert K.n_bucket_sharded(100_000, 4) % (4 * K.LANE) == 0


# ----------------------------------------------------------------------
# padded-constant cache: stale-generation eviction
# ----------------------------------------------------------------------

def test_catalog_cache_keeps_one_live_copy_per_constant():
    """Regression for the duplication bug: growing the catalog rebuilds
    the embedding matrix; the old generations' padded device copies
    must die with their source arrays instead of accumulating one
    near-identical multi-MB pack per historical size."""
    K.reset_catalog_cache()
    mres = random_catalog(24, seed=3)
    eng = RoutingEngine(mres, knn_k=4)
    prefs, sigs = random_queries(3, seed=3)
    for i in range(6):
        eng.route_many_batch(prefs, sigs)
        mres.register(make_entry(f"grow{i}", task_types=("chat",),
                                 generalist=True))
    gc.collect()
    eng.route_many_batch(prefs, sigs)
    info = K.catalog_cache_info()
    # every stale generation was evicted: only the live embedding's
    # pack remains, exactly one copy per constant
    assert info["entries"] == 1, info
    assert len(info["keys"]) == len(set(info["keys"]))
    assert {key[0] for key in info["keys"]} == {id(mres.embeddings())}


def test_catalog_cache_capped_with_live_variants():
    """Distinct live variants (fp32/quant/ivf on the same snapshot) all
    cache — bounded by the cap."""
    K.reset_catalog_cache()
    mres = random_catalog(48, seed=7)
    prefs, sigs = random_queries(4, seed=7)
    engines = [RoutingEngine(mres, knn_k=4),
               RoutingEngine(mres, knn_k=4, quantize=True),
               RoutingEngine(mres, knn_k=4, ivf=True, ivf_min_n=1),
               RoutingEngine(mres, knn_k=4, quantize=True, ivf=True,
                             ivf_min_n=1)]
    models = [e.route_many_batch(prefs, sigs).models() for e in engines]
    info = K.catalog_cache_info()
    assert 1 <= info["entries"] <= K._CATALOG_CACHE_MAX
    assert len(info["keys"]) == len(set(info["keys"]))
    # cache hits must not change decisions
    assert engines[0].route_many_batch(prefs, sigs).models() == models[0]
