"""Semantic response cache: packed-array store semantics (threshold,
fingerprint gate, TTL, LRU, quality bar, kernel parity, state
round-trip), the Zipf replay workload, and the serving-engine
integration (hit short-circuit, funnel accounting, observe
write-back)."""
import numpy as np
import pytest

from repro.cache import (CACHE_KINDS, SemanticCache, prefs_fingerprint,
                         text_sketch)
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature
from repro.core.telemetry import Telemetry
from repro.data.workload import ZipfReplayScenario, zipf_replay
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import LoadTracker
from tests.test_routing_batch import StubAnalyzer, random_catalog

BAL = "balanced"


def _cache(**kw):
    kw.setdefault("capacity", 16)
    kw.setdefault("threshold", 0.95)
    kw.setdefault("min_quality", 0.5)
    kw.setdefault("sketch_dims", 16)
    return SemanticCache(**kw)


# ----------------------------------------------------------------------
# keys & fingerprints
# ----------------------------------------------------------------------

def test_text_sketch_deterministic_and_normalized():
    s1 = text_sketch(["hello world foo", "bar baz"], dims=16)
    s2 = text_sketch(["hello world foo", "bar baz"], dims=16)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_allclose(np.linalg.norm(s1, axis=1), 1.0, atol=1e-5)
    # identical texts sketch identically; disjoint texts do not
    assert float(s1[0] @ s1[0]) == pytest.approx(1.0)
    assert float(s1[0] @ s1[1]) < 0.99


def test_prefs_fingerprint_gates_exactly():
    assert prefs_fingerprint(BAL) == prefs_fingerprint(BAL)
    assert prefs_fingerprint(BAL) != prefs_fingerprint("accuracy-first")
    # dict and profile resolving to the same weights share a fingerprint
    from repro.core.preferences import PROFILES
    assert prefs_fingerprint(dict(PROFILES[BAL].weights)) == \
        prefs_fingerprint(BAL)


def test_keys_for_shapes_and_exact_repeat():
    c = _cache()
    keys = c.keys_for([BAL, BAL], ["same text here", "same text here"])
    assert keys.shape == (2, c.dim)
    np.testing.assert_array_equal(keys[0], keys[1])
    with pytest.raises(ValueError):
        c.keys_for([BAL], ["a", "b"])


# ----------------------------------------------------------------------
# store semantics
# ----------------------------------------------------------------------

def test_lookup_hit_miss_threshold_and_fingerprint():
    c = _cache()
    keys = c.keys_for([BAL, BAL], ["alpha beta gamma", "delta epsilon zeta"])
    fps = c.fingerprints([BAL, BAL])
    hit, slot, sim = c.lookup(keys, fps)
    assert not hit.any() and (slot == -1).all()
    assert c.put(keys[0], int(fps[0]), "m0", np.arange(4), 0.9,
                 sig=TaskSignature()) == "stored"
    hit, slot, sim = c.lookup(keys, fps)
    assert hit[0] and not hit[1]
    assert sim[0] >= c.threshold and np.isneginf(sim[1])
    e = c.get(int(slot[0]))
    assert e.model == "m0" and e.quality == pytest.approx(0.9)
    np.testing.assert_array_equal(e.response, np.arange(4))
    # same key, different prefs fingerprint -> miss
    other = c.fingerprints(["accuracy-first"])
    assert not c.lookup(keys[:1], other)[0][0]


def test_put_rejects_below_quality_bar():
    c = _cache(min_quality=0.6)
    k = c.keys_for([BAL], ["q text"])
    assert c.put(k[0], 1, "m", None, 0.59) == "rejected"
    assert len(c) == 0 and c.stats()["rejected"] == 1


def test_put_dedups_semantic_duplicates():
    c = _cache(min_quality=0.0)
    k = c.keys_for([BAL, BAL], ["query one two", "query one two"])
    c.put(k[0], 5, "m0", np.array([1]), 0.7)
    c.put(k[1], 5, "m1", np.array([2]), 0.9)     # better -> replaces
    assert len(c) == 1
    hit, slot, _ = c.lookup(k[:1], np.array([5]))
    e = c.get(int(slot[0]))
    assert e.model == "m1" and e.quality == pytest.approx(0.9)
    # a WORSE duplicate refreshes recency but keeps the stronger answer
    c.put(k[0], 5, "m2", np.array([3]), 0.2)
    assert len(c) == 1
    assert c.get(int(slot[0])).model == "m1"


def test_lru_eviction_keeps_arrays_bounded():
    c = _cache(capacity=2, threshold=0.999, min_quality=0.0)
    texts = ["aa bb cc", "dd ee ff", "gg hh ii"]
    keys = c.keys_for([BAL] * 3, texts)
    c.put(keys[0], 1, "m0", None, 1.0)
    c.put(keys[1], 1, "m1", None, 1.0)
    c.lookup(keys[:1], np.array([1]))            # touch entry 0 (MRU)
    c.put(keys[2], 1, "m2", None, 1.0)           # evicts entry 1 (LRU)
    assert len(c) == 2 and c.stats()["evicted"] == 1
    hit, _, _ = c.lookup(keys, np.array([1, 1, 1]))
    assert hit.tolist() == [True, False, True]


def test_ttl_expiry():
    now = [0.0]
    c = _cache(ttl_s=10.0, min_quality=0.0, time_fn=lambda: now[0])
    k = c.keys_for([BAL], ["some text"])
    c.put(k[0], 1, "m", None, 1.0)
    assert c.lookup(k, np.array([1]))[0][0]
    now[0] = 10.1
    assert not c.lookup(k, np.array([1]))[0][0]
    assert c.stats()["expired"] == 1 and len(c) == 0


def test_kernel_lookup_matches_numpy():
    rng = np.random.default_rng(3)
    cn = _cache(capacity=32, min_quality=0.0)
    ck = _cache(capacity=32, min_quality=0.0, use_kernel=True,
                kernel_min_n=0)
    texts = [f"query number {i} about topic {i % 5}" for i in range(12)]
    keys = cn.keys_for([BAL] * 12, texts)
    fps = cn.fingerprints([BAL] * 12)
    for j in rng.choice(12, 7, replace=False):
        for c in (cn, ck):
            c.put(keys[j], int(fps[j]), f"m{j}", None, 1.0)
    hn = cn.lookup(keys, fps)
    hk = ck.lookup(keys, fps)
    np.testing.assert_array_equal(hn[0], hk[0])
    np.testing.assert_array_equal(hn[1], hk[1])
    np.testing.assert_allclose(hn[2][hn[0]], hk[2][hk[0]], atol=1e-5)


def test_state_round_trip_bit_exact():
    c = _cache(min_quality=0.0)
    keys = c.keys_for([BAL] * 3, ["a b", "c d", "e f"])
    fps = c.fingerprints([BAL] * 3)
    c.put(keys[0], int(fps[0]), "m0", np.arange(3), 0.8,
          sig=TaskSignature(task_type="code", domain="software"))
    c.put(keys[1], int(fps[1]), "m1", None, 0.6)
    c2 = _cache()
    c2.load_state(c.state())
    np.testing.assert_array_equal(c.vecs, c2.vecs)
    np.testing.assert_array_equal(c.valid, c2.valid)
    h1 = c.lookup(keys, fps)
    h2 = c2.lookup(keys, fps)
    np.testing.assert_array_equal(h1[0], h2[0])
    np.testing.assert_array_equal(h1[1], h2[1])
    e = c2.get(int(h2[1][0]))
    assert e.model == "m0" and e.sig.task_type == "code"
    with pytest.raises(ValueError, match="dim"):
        _cache(sketch_dims=8).load_state(c.state())


# ----------------------------------------------------------------------
# Zipf replay workload
# ----------------------------------------------------------------------

def test_zipf_replay_deterministic_and_repeat_heavy():
    sc = ZipfReplayScenario(n_unique=32, n_requests=256, zipf_a=1.1,
                            seed=3)
    pool1, order1 = zipf_replay(sc)
    pool2, order2 = zipf_replay(sc)
    assert [q.text for q in pool1] == [q.text for q in pool2]
    np.testing.assert_array_equal(order1, order2)
    assert len(pool1) == 32 and order1.shape == (256,)
    assert order1.min() >= 0 and order1.max() < 32
    # repeat-heavy: the steady-state repeat fraction clears the 50%
    # hit-rate bar the cache benchmark asserts
    repeats = 256 - len(np.unique(order1))
    assert repeats / 256 >= 0.5
    # the head dominates: rank-0 traffic far above uniform
    assert (order1 == order1[np.argmax(np.bincount(order1))]).mean() \
        > 3.0 / 32
    np.testing.assert_allclose(sc.rank_probs.sum(), 1.0)
    with pytest.raises(AssertionError):
        ZipfReplayScenario(n_unique=0).validate()


# ----------------------------------------------------------------------
# serving-engine integration
# ----------------------------------------------------------------------

def _serving(cache=None, load=None, load_weight=0.0):
    m = random_catalog(10, seed=6)
    router = OptiRoute(m, StubAnalyzer(), telemetry=Telemetry(),
                       cache=cache, load=load, load_weight=load_weight)
    return ServingEngine(router), router


def test_engine_hit_short_circuits_and_funnels():
    cache = _cache(capacity=64, min_quality=0.3)
    engine, router = _serving(cache)
    reqs = [Request(text=f"question {i % 3} here", prefs=BAL, id=i)
            for i in range(6)]
    out1 = engine.submit(reqs)
    assert not any(r.cache_hit for r in out1)
    engine.observe(out1, [0.9] * 6)              # validate -> write back
    out2 = engine.submit(reqs)
    assert all(r.cache_hit for r in out2)
    for a, b in zip(out1, out2):
        assert b.model == a.model                # replays the stored model
        assert b.rq is None                      # no bandit/write-back handle
        assert b.sim_latency_s == 0.0
    funnel = router.telemetry.cache_funnel()
    assert funnel["hit"] == 6 and funnel["miss"] == 6
    assert funnel["stored"] == 6
    # hits take no admission outcome and no per-model latency row
    s = engine.summary()
    assert s["cache_hits"] == 6
    assert sum(s["models"].values()) == 6        # only the miss pass
    # telemetry routing events: only misses were routed
    assert len(router.telemetry._events) == 6


def test_engine_hit_takes_no_load_slot():
    cache = _cache(capacity=64, min_quality=0.0)
    lt = LoadTracker(10, capacity=2.0)
    engine, router = _serving(cache, load=lt, load_weight=1.0)
    reqs = [Request(text="same question", prefs=BAL, id=i,
                    deadline_ms=60_000.0) for i in range(4)]
    out1 = engine.submit(reqs)
    engine.observe(out1, [1.0] * 4)
    before = lt.snapshot()
    out2 = engine.submit(reqs)
    assert all(r.cache_hit for r in out2)
    after = lt.snapshot()
    for a, b in zip(before, after):              # no admit/start/finish
        np.testing.assert_array_equal(a, b)
    # no admission outcomes recorded for hits
    assert router.telemetry.admission_funnel() == \
        {"admitted": 4}                          # first pass only


def test_low_quality_responses_never_cached():
    cache = _cache(capacity=64, min_quality=0.5)
    engine, router = _serving(cache)
    reqs = [Request(text="q text", prefs=BAL, id=0)]
    out = engine.submit(reqs)
    engine.observe(out, [0.2])
    assert router.telemetry.cache_funnel()["rejected"] == 1
    assert not engine.submit(reqs)[0].cache_hit


def test_observe_writes_back_once():
    cache = _cache(capacity=64, min_quality=0.0)
    engine, router = _serving(cache)
    out = engine.submit([Request(text="q", prefs=BAL, id=0)])
    engine.observe(out, [0.9])
    engine.observe(out, [0.9])                   # observed-once guard
    assert router.telemetry.cache_funnel()["stored"] == 1


def test_cache_write_back_without_bandit():
    """observe() must write back even when no adaptive bandit is
    attached — the cache is its own consumer of validated outcomes."""
    cache = _cache(capacity=64, min_quality=0.0)
    engine, router = _serving(cache)
    assert router.adaptive is None
    out = engine.submit([Request(text="q", prefs=BAL, id=0)])
    assert engine.observe(out, [0.9]) is None    # no rewards (no bandit)
    assert len(cache) == 1
    assert engine.submit([Request(text="q", prefs=BAL, id=1)])[0].cache_hit


def test_different_prefs_never_share_entries():
    cache = _cache(capacity=64, min_quality=0.0)
    engine, _ = _serving(cache)
    out = engine.submit([Request(text="same text", prefs=BAL, id=0)])
    engine.observe(out, [1.0])
    r = engine.submit([Request(text="same text", prefs="accuracy-first",
                               id=1)])[0]
    assert not r.cache_hit


def test_write_back_with_auto_observing_reward_fn():
    """Regression: with adaptive + reward_fn + cache all attached,
    route_all's auto-observe consumes bandit freshness BEFORE the
    engine stamps cache keys — the post-generation observe() must
    still write the cache (cache_written is tracked separately from
    observed)."""
    from repro.adaptive import LinearBandit
    cache = _cache(capacity=64, min_quality=0.0)
    m = random_catalog(10, seed=6)
    router = OptiRoute(m, StubAnalyzer(), telemetry=Telemetry(),
                       cache=cache, adaptive=LinearBandit(10),
                       adaptive_weight=0.5, reward_fn=lambda rq: 0.7)
    engine = ServingEngine(router)
    out = engine.submit([Request(text="q text here", prefs=BAL, id=0)])
    assert out[0].rq.observed          # auto-observed inside route_all
    engine.observe(out, [0.9])         # post-generation ground truth
    assert len(cache) == 1             # ...still written back
    assert cache.get(int(np.flatnonzero(cache.valid)[0])).quality == \
        pytest.approx(0.9)             # the REAL quality, not reward_fn's
    assert engine.submit([Request(text="q text here", prefs=BAL,
                                  id=1)])[0].cache_hit
    # and never written twice
    engine.observe(out, [0.9])
    assert router.telemetry.cache_funnel()["stored"] == 1


def test_engine_attached_cache_reaches_write_back():
    """Regression: a cache attached via ServingEngine(cache=...) on a
    cache-less router must still be written by the router's observe()
    (the engine shares it onto the router)."""
    cache = _cache(capacity=64, min_quality=0.0)
    m = random_catalog(10, seed=6)
    router = OptiRoute(m, StubAnalyzer(), telemetry=Telemetry())
    engine = ServingEngine(router, cache=cache)
    assert router.cache is cache
    out = engine.submit([Request(text="q", prefs=BAL, id=0)])
    engine.observe(out, [0.9])
    assert len(cache) == 1
    assert engine.submit([Request(text="q", prefs=BAL, id=1)])[0].cache_hit


def test_max_new_joins_the_fingerprint_gate():
    """A response generated under max_new=4 must never answer a
    max_new=256 request: the decoding budget is part of the exact-match
    gate."""
    cache = _cache(capacity=64, min_quality=0.0)
    engine, _ = _serving(cache)
    out = engine.submit([Request(text="same text", prefs=BAL, id=0,
                                 max_new=4)])
    engine.observe(out, [1.0])
    assert engine.submit([Request(text="same text", prefs=BAL, id=1,
                                  max_new=4)])[0].cache_hit
    assert not engine.submit([Request(text="same text", prefs=BAL, id=2,
                                      max_new=256)])[0].cache_hit


def test_conflicting_engine_and_router_caches_raise():
    m = random_catalog(6, seed=1)
    router = OptiRoute(m, StubAnalyzer(), cache=_cache())
    with pytest.raises(ValueError, match="ONE store"):
        ServingEngine(router, cache=_cache())
    # same store twice is fine
    ServingEngine(router, cache=router.cache)


def test_eviction_and_expiry_reach_the_funnel():
    """cache_funnel's evicted/expired keys must reflect internal churn
    (put-time LRU evictions, lookup-time TTL purges), not stay zero."""
    now = [0.0]
    cache = _cache(capacity=2, threshold=0.999, min_quality=0.0,
                   ttl_s=50.0, time_fn=lambda: now[0])
    engine, router = _serving(cache)
    for i, text in enumerate(["aa bb", "cc dd", "ee ff"]):
        out = engine.submit([Request(text=text, prefs=BAL, id=i)])
        engine.observe(out, [1.0])
    funnel = router.telemetry.cache_funnel()
    assert funnel["evicted"] == 1                # 3 inserts, 2 slots
    now[0] = 60.0
    engine.submit([Request(text="aa bb", prefs=BAL, id=9)])
    assert router.telemetry.cache_funnel()["expired"] == 2


def test_load_state_preserves_configured_capacity():
    """Restoring an old (smaller) snapshot must not shrink a cache
    that was reconfigured larger — live entries compact in."""
    small = _cache(capacity=4, min_quality=0.0)
    keys = small.keys_for([BAL] * 3, ["a b", "c d", "e f"])
    fps = small.fingerprints([BAL] * 3)
    for k, f, m in zip(keys, fps, ("m0", "m1", "m2")):
        small.put(k, int(f), m, None, 1.0)
    big = _cache(capacity=64, min_quality=0.0)
    big.load_state(small.state())
    assert big.capacity == 64 and len(big) == 3
    hit, _, _ = big.lookup(keys, fps)
    assert hit.all()
    # ...and a snapshot with more live entries than capacity refuses
    tiny = _cache(capacity=2, min_quality=0.0)
    with pytest.raises(ValueError, match="live"):
        tiny.load_state(small.state())


def test_no_cache_engine_unchanged():
    engine, router = _serving(None)
    out = engine.submit([Request(text="q", prefs=BAL, id=0)])
    assert not out[0].cache_hit
    assert router.telemetry.cache_funnel() == {k: 0 for k in CACHE_KINDS}
    assert engine.observe(out, [0.9]) is None
