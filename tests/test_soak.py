"""Soak-harness building blocks: multi-tenant arrival traces, Jain
fairness, the bare-name gauge/counter surface the soak SLO gate reads,
and a miniature deterministic run of the virtual-time engine replay
(fault injection + restart transparency) from ``benchmarks.soak``."""
import numpy as np
import pytest

from repro.core.telemetry import Telemetry
from repro.data.workload import (MultiTenantScenario, TenantSpec,
                                 TrafficScenario, jain_fairness,
                                 multi_tenant_arrivals)
from repro.obs.export import metrics_from_prom, prometheus_text

BASE = TrafficScenario(duration_s=8.0, base_rate=6.0, burst_rate=24.0,
                       deadline_ms=400.0, seed=5)


def _mt(**kw):
    tenants = kw.pop("tenants", (
        TenantSpec("acme", weight=2.0),
        TenantSpec("globex"),
        TenantSpec("flood", rate_scale=3.0, rate_limit=8.0,
                   deadline_ms=250.0)))
    return MultiTenantScenario(base=kw.pop("base", BASE), tenants=tenants)


# ----------------------------------------------------------------------
# multi-tenant traffic
# ----------------------------------------------------------------------

def test_multi_tenant_arrivals_deterministic_and_sorted():
    sc = _mt()
    t1, i1 = multi_tenant_arrivals(sc)
    t2, i2 = multi_tenant_arrivals(sc)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(i1, i2)
    assert (np.diff(t1) >= 0).all()
    assert t1.size > 0 and t1.max() < BASE.duration_s
    assert set(np.unique(i1)) == {0, 1, 2}


def test_multi_tenant_rate_scale_shapes_volume():
    t, i = multi_tenant_arrivals(_mt())
    counts = np.bincount(i, minlength=3).astype(float)
    # flood draws at 3x the base rates: ~3x the quiet tenants' volume
    quiet = counts[:2].mean()
    assert 2.0 * quiet < counts[2] < 4.5 * quiet
    # per-tenant processes are independently seeded, not clones
    assert not np.array_equal(t[i == 0][:10], t[i == 1][:10])


def test_deadline_ms_of_override():
    sc = _mt()
    assert sc.deadline_ms_of(0) == BASE.deadline_ms
    assert sc.deadline_ms_of(2) == 250.0


def test_multi_tenant_validation():
    with pytest.raises(AssertionError, match="duplicate"):
        _mt(tenants=(TenantSpec("a"), TenantSpec("a"))).validate()
    with pytest.raises(AssertionError):
        _mt(tenants=()).validate()


# ----------------------------------------------------------------------
# fairness index
# ----------------------------------------------------------------------

def test_jain_fairness_properties():
    assert jain_fairness([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([5.0]) == pytest.approx(1.0)
    assert jain_fairness([]) == 1.0
    assert jain_fairness([0.0, 0.0]) == 1.0
    # one tenant hogging everything floors at 1/n
    assert jain_fairness([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    mild = jain_fairness([1.0, 0.8, 0.9])
    assert 0.9 < mild < 1.0


# ----------------------------------------------------------------------
# exported SLO surface (what the CI soak gate evaluates)
# ----------------------------------------------------------------------

def test_metrics_from_prom_bare_gauges_and_tenant_shed_rates():
    tel = Telemetry()
    tel.set_gauge("soak_p999_s", 0.104)
    tel.set_gauge("soak_post_warmup_compiles", 0.0)
    tel.inc("intake_rate_limited", 7)
    for _ in range(9):
        tel.record_admission("admitted", tenant="acme")
    tel.record_admission("shed", tenant="acme")
    for _ in range(4):
        tel.record_admission("shed", tenant="flood")
    tel.record_admission("admitted", tenant="flood")
    m = metrics_from_prom(prometheus_text(tel))
    # generic gauges/counters surface under their bare names so the
    # label-free SLO rule grammar can target them
    assert m["soak_p999_s"] == pytest.approx(0.104)
    assert m["soak_post_warmup_compiles"] == 0.0
    assert m["intake_rate_limited"] == 7.0
    # per-tenant shed rates are derived from the tenant funnel
    assert m["tenant_shed_rate_acme"] == pytest.approx(0.1)
    assert m["tenant_shed_rate_flood"] == pytest.approx(0.8)
    assert m["tenant_shed_rate_max"] == pytest.approx(0.8)


# ----------------------------------------------------------------------
# miniature engine soak (virtual time, deterministic)
# ----------------------------------------------------------------------

def _tiny_scenario():
    return MultiTenantScenario(
        base=TrafficScenario(duration_s=4.0, base_rate=5.0,
                             burst_rate=15.0, deadline_ms=400.0, seed=3),
        tenants=(TenantSpec("acme", weight=2.0),
                 TenantSpec("flood", rate_scale=3.0, rate_limit=6.0,
                            deadline_ms=300.0)))


def test_replay_engine_soak_restart_is_transparent(tmp_path):
    soak = pytest.importorskip("benchmarks.soak")
    sc = _tiny_scenario()
    tel = Telemetry()
    control = soak.replay_engine_soak(sc, tel, max_batch=8,
                                      max_wait_s=0.05)
    restart = soak.replay_engine_soak(
        sc, tel, max_batch=8, max_wait_s=0.05, restart_t=2.0,
        ckpt_path=str(tmp_path / "router.npz"))
    assert restart["restarted"]
    assert restart["outcomes"] == control["outcomes"]
    assert control["requests"] == len(control["outcomes"])
    # the flooding tenant was limited at intake; quiet tenant was not
    assert control["intake"]["flood"]["rate_limited"] > 0
    assert control["tally"]["acme"]["shed"] == 0
    # jit caches are module-level: the second full run recompiled nothing
    assert (tel.route_step_stats()["compiles"]
            == control["compiles_after_warmup"])


def test_replay_engine_soak_fault_degrades_only_hot_group():
    soak = pytest.importorskip("benchmarks.soak")
    sc = _tiny_scenario()
    tel = Telemetry()
    res = soak.replay_engine_soak(sc, tel, max_batch=8, max_wait_s=0.05,
                                  fail_t=1.0)
    assert res["fault_seen"]
    failed = [(rid, tenant, model)
              for rid, tenant, adm, model in res["outcomes"]
              if adm == "failed"]
    assert failed, "injected fault produced no failed outcomes"
    assert all(model == soak.HOT for _, _, model in failed)
    # the batch survived: every arrival still has exactly one outcome
    assert len(res["outcomes"]) == res["requests"]
