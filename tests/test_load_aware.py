"""Load- and SLO-aware routing: LoadTracker state machine, the
load_weight scoring term (numpy + kernel paths), deadline admission in
the serving engine, and the discrete-event traffic simulator."""
import threading

import numpy as np
import pytest

from repro.core.mres import MRES
from repro.core.preferences import TaskSignature
from repro.core.routing import RoutingEngine
from repro.core.telemetry import Telemetry
from repro.data.workload import (ServingSimulator, TrafficScenario,
                                 poisson_arrivals)
from repro.serving.load import ADMISSION_KINDS, LoadTracker, plan_admission
from tests.conftest import make_entry


def _flat_catalog(n=6, accuracy_step=0.05):
    """All-chat catalog with a strict accuracy ordering (m0 best)."""
    m = MRES()
    for i in range(n):
        m.register(make_entry(
            f"m{i}", accuracy=0.9 - accuracy_step * i,
            latency_ms=50.0 + 10 * i, cost=1.0 + i,
            task_types=("chat",), domains=("general",), generalist=True))
    return m


SIG = TaskSignature(task_type="chat", domain="general", complexity=0.2)


# ----------------------------------------------------------------------
# LoadTracker state machine
# ----------------------------------------------------------------------

def test_tracker_lifecycle_counts():
    lt = LoadTracker(3, capacity=2.0)
    lt.admit(0)
    lt.admit(0)
    lt.admit_many(np.array([1, 1, 1, 2]))
    q, f, c, _ = lt.snapshot()
    assert q.tolist() == [2, 3, 1] and f.tolist() == [0, 0, 0]
    lt.start(0)
    q, f, _, _ = lt.snapshot()
    assert q[0] == 1 and f[0] == 1
    lt.finish(0, 0.5)
    q, f, _, _ = lt.snapshot()
    assert f[0] == 0
    # finish never drives counters negative
    lt.finish(2)
    assert lt.snapshot()[1][2] == 0


def test_tracker_ewma_and_wait_estimates():
    lt = LoadTracker(2, capacity=2.0, ewma_alpha=0.5,
                     default_service_s=0.1)
    # 4 outstanding on capacity 2 at 0.1s each: 3 completions must land
    # before a new arrival starts, draining 2 per 0.1s -> 0.15s wait
    lt.admit(0, count=4)
    np.testing.assert_allclose(lt.estimated_wait_s(), [0.15, 0.0],
                               atol=1e-6)
    np.testing.assert_allclose(lt.estimated_latency_s([0]), [0.25],
                               atol=1e-6)
    # EWMA folds realized service times
    lt.start(0)
    lt.finish(0, 0.3)
    assert lt.snapshot()[3][0] == pytest.approx(0.2)
    # penalty saturates in [0, 1) and is monotone in queue depth
    p1 = lt.penalty()[0]
    lt.admit(0, count=50)
    p2 = lt.penalty()[0]
    assert 0.0 <= p1 < p2 < 1.0
    assert lt.penalty()[1] == 0.0


def test_tracker_ensure_growth_and_capacity():
    lt = LoadTracker(2, capacity=4.0)
    lt.admit(1)
    lt.ensure(5, capacity=[1.0, 2.0, 8.0])
    assert lt.n_models == 5
    q, _, c, _ = lt.snapshot()
    assert q.tolist() == [0, 1, 0, 0, 0]
    assert c.tolist() == [4.0, 4.0, 1.0, 2.0, 8.0]
    lt.ensure(3)                        # shrink is a no-op
    assert lt.n_models == 5
    lt.set_capacity(0, 16.0)
    assert lt.snapshot()[2][0] == 16.0


def test_idle_capacity_has_zero_wait():
    """Regression: one in-flight request on a 4-slot model must not be
    penalized over an idle one — expected wait stays 0 until
    queue + inflight >= capacity (the old (q+f)/c*s estimate reported
    nonzero wait for a model with free slots)."""
    lt = LoadTracker(2, capacity=4.0, default_service_s=0.1)
    lt.admit(0)
    lt.start(0)                          # 1 in flight, 3 slots free
    q, f, c, _ = lt.snapshot()
    assert q[0] == 0 and f[0] == 1 and c[0] == 4.0
    np.testing.assert_allclose(lt.estimated_wait_s(), [0.0, 0.0])
    np.testing.assert_allclose(lt.penalty(), [0.0, 0.0])
    # the estimate turns on exactly at saturation
    lt.admit(0, count=3)                 # q+f == capacity
    assert lt.estimated_wait_s()[0] > 0.0
    assert lt.estimated_wait_s()[1] == 0.0


def test_ensure_accepts_full_length_capacity():
    """Regression: ensure() used to reshape(grow) the capacity input
    and crash on a full-length (n_models,) vector."""
    lt = LoadTracker(2, capacity=4.0)
    full = np.array([9.0, 9.0, 1.0, 2.0, 8.0], np.float32)
    lt.ensure(5, capacity=full)          # full catalog vector: tail
    assert lt.snapshot()[2].tolist() == [4.0, 4.0, 1.0, 2.0, 8.0]
    lt.ensure(6, capacity=[16.0])        # new-arms-only still works
    assert lt.snapshot()[2].tolist() == [4.0, 4.0, 1.0, 2.0, 8.0, 16.0]
    with pytest.raises(ValueError, match="capacity"):
        lt.ensure(8, capacity=[1.0, 2.0, 3.0])   # neither 2 nor 8
    lt.ensure(3, capacity=np.ones(3))    # no growth -> no-op
    assert lt.n_models == 6


def test_tracker_thread_safety():
    lt = LoadTracker(4, capacity=2.0)
    errs = []

    def worker(i):
        try:
            for _ in range(500):
                lt.admit(i % 4)
                lt.start(i % 4)
                lt.finish(i % 4, 0.01)
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    q, f, _, _ = lt.snapshot()
    assert (q == 0).all() and (f == 0).all()


# ----------------------------------------------------------------------
# load term in the routing blend
# ----------------------------------------------------------------------

def test_load_weight_zero_matches_no_tracker():
    m = _flat_catalog()
    lt = LoadTracker(len(m))
    lt.admit(0, count=100)              # saturate the static winner
    d0 = RoutingEngine(m).route("accuracy-first", SIG)
    d1 = RoutingEngine(m, load=lt, load_weight=0.0).route(
        "accuracy-first", SIG)
    assert d0.model == d1.model and d0.score == pytest.approx(d1.score)


def test_saturated_model_loses_to_alternate():
    m = _flat_catalog()
    lt = LoadTracker(len(m), capacity=2.0)
    eng = RoutingEngine(m, load=lt, load_weight=1.0)
    assert eng.route("accuracy-first", SIG).model == "m0"
    lt.admit(0, count=200)              # m0 saturates -> penalty ~ 1
    d = eng.route("accuracy-first", SIG)
    assert d.model != "m0"
    lt.reset()                          # drained -> winner returns
    assert eng.route("accuracy-first", SIG).model == "m0"


def test_load_penalty_reaches_fallback_scorer():
    m = MRES()
    m.register(make_entry("gen-a", accuracy=0.9, task_types=("chat",),
                          generalist=True))
    m.register(make_entry("gen-b", accuracy=0.8, task_types=("chat",),
                          generalist=True))
    lt = LoadTracker(2, capacity=1.0)
    eng = RoutingEngine(m, load=lt, load_weight=2.0)
    sig = TaskSignature(task_type="vqa", domain="healthcare")
    assert eng.route("accuracy-first", sig).fallback_kind == "generalist"
    assert eng.route("accuracy-first", sig).model == "gen-a"
    lt.admit(0, count=100)
    d = eng.route("accuracy-first", sig)
    assert d.used_fallback and d.model == "gen-b"


def test_load_kernel_matches_numpy_path():
    from tests.test_routing_batch import random_catalog, random_queries
    m = random_catalog(96, seed=13)
    lt = LoadTracker(96, capacity=2.0)
    rng = np.random.default_rng(3)
    lt.admit_many(rng.integers(0, 96, 400))
    prefs, sigs = random_queries(11, seed=13)
    eng_np = RoutingEngine(m, knn_k=8, load=lt, load_weight=1.5)
    eng_k = RoutingEngine(m, knn_k=8, load=lt, load_weight=1.5,
                          use_kernel=True)
    eng_k._kernel_min_n = 0
    for a, b in zip(eng_np.route_many(prefs, sigs),
                    eng_k.route_many(prefs, sigs)):
        assert a.model == b.model
        assert a.fallback_kind == b.fallback_kind
        assert a.score == pytest.approx(b.score, abs=1e-5)


def test_load_route_single_matches_batch():
    from tests.test_routing_batch import random_catalog, random_queries
    m = random_catalog(32, seed=21)
    lt = LoadTracker(32, capacity=2.0)
    lt.admit_many(np.random.default_rng(0).integers(0, 32, 100))
    eng = RoutingEngine(m, knn_k=8, load=lt, load_weight=1.0)
    prefs, sigs = random_queries(9, seed=21)
    batch = eng.route_many(prefs, sigs)
    for d_b, p, s in zip(batch, prefs, sigs):
        d_1 = eng.route(p, s)
        assert d_b.model == d_1.model
        assert d_b.score == pytest.approx(d_1.score, abs=1e-6)


def test_load_penalty_counted_once_fused_vs_unfused():
    """Regression: the load penalty must affect the final score exactly
    once, at the candidate-column scoring blend.  The old path ALSO
    fused -penalty into the kNN similarity search, where it crowded a
    loaded model out of the candidate set entirely (an unbounded second
    application) — with knn_k < n the loaded-but-still-best model lost
    to a strictly worse alternate."""
    m = _flat_catalog(6)
    lt = LoadTracker(6, capacity=2.0)
    lt.admit(0, count=5)                 # modest load on the leader
    lt.admit(1, count=2)
    eng = RoutingEngine(m, knn_k=4, load=lt, load_weight=1.0)
    d = eng.route_many(["accuracy-first"], [SIG])[0]
    emb, names, *_ = m.snapshot()
    from repro.core.preferences import resolve
    W = resolve("accuracy-first").vector()
    lpen = 1.0 * lt.penalty()
    # brute-force reference: blend over the FULL catalog, penalty once
    ref = emb @ W - lpen
    assert d.model == names[int(np.argmax(ref))]
    assert d.score == pytest.approx(float(ref.max()), abs=1e-5)
    # every surfaced candidate's score carries the penalty exactly once
    for nm, s in d.candidates:
        j = names.index(nm)
        assert s == pytest.approx(float(ref[j]), abs=1e-5)
    # parity pin: an explicitly unfused kNN (bias stripped) must be
    # decision- and score-identical to the engine's own path
    eng2 = RoutingEngine(m, knn_k=4, load=lt, load_weight=1.0)
    orig = eng2._knn_batch
    eng2._knn_batch = \
        lambda T, k, ti, di, snap, bias=None: orig(T, k, ti, di, snap,
                                                   bias=None)
    d2 = eng2.route_many(["accuracy-first"], [SIG])[0]
    assert (d.model, d.fallback_kind) == (d2.model, d2.fallback_kind)
    assert d.score == pytest.approx(d2.score, abs=1e-6)
    assert d.candidates == d2.candidates


def test_fallback_scorer_penalty_counted_once():
    """The fallback ladder's dense scorer applies the same
    penalty-exactly-once blend as the primary path."""
    m = MRES()
    m.register(make_entry("gen-a", accuracy=0.9, task_types=("chat",),
                          generalist=True))
    m.register(make_entry("gen-b", accuracy=0.8, task_types=("chat",),
                          generalist=True))
    lt = LoadTracker(2, capacity=1.0)
    lt.admit(0, count=10)
    eng = RoutingEngine(m, load=lt, load_weight=2.0)
    sig = TaskSignature(task_type="vqa", domain="healthcare")
    d = eng.route("accuracy-first", sig)
    assert d.used_fallback
    emb, names, *_ = m.snapshot()
    from repro.core.preferences import resolve
    W = resolve("accuracy-first").vector()
    ref = emb @ W - 2.0 * lt.penalty()
    for nm, s in d.candidates:
        assert s == pytest.approx(float(ref[names.index(nm)]), abs=1e-5)


# ----------------------------------------------------------------------
# deadline admission planning
# ----------------------------------------------------------------------

def _decision(eng, prefs="accuracy-first", sig=SIG):
    return eng.route(prefs, sig)


def test_plan_admission_paths():
    m = _flat_catalog(3)
    lt = LoadTracker(3, capacity=1.0, default_service_s=0.1)
    col = {f"m{i}": i for i in range(3)}
    eng = RoutingEngine(m, knn_k=3)          # load-blind routing...
    d = _decision(eng)
    # no deadline / no tracker -> admitted untouched
    assert plan_admission(d, lt, col, None) == (d.model, "admitted", 0.0)
    assert plan_admission(d, None, col, 100.0)[1] == "admitted"
    # idle catalog: the routed model fits its SLO
    model, kind, est = plan_admission(d, lt, col, 1000.0)
    assert (model, kind) == (d.model, "admitted") and est > 0.0
    # saturate the winner: reroute to the best-scoring candidate that fits
    lt.admit(col[d.model], count=50)
    model, kind, _ = plan_admission(d, lt, col, 1000.0)
    assert kind == "rerouted" and model != d.model
    second = [c for c, _ in d.candidates][1]
    assert model == second
    # impossible SLO anywhere -> shed
    model, kind, est = plan_admission(d, lt, col, 0.001)
    assert kind == "shed" and est > 0.001 / 1e3
    assert kind in ADMISSION_KINDS


# ----------------------------------------------------------------------
# serving engine integration
# ----------------------------------------------------------------------

def _serving_setup(deadline_ms=None):
    from repro.core.orchestrator import OptiRoute
    from repro.serving.engine import Request, ServingEngine
    from tests.test_routing_batch import StubAnalyzer
    m = _flat_catalog()
    lt = LoadTracker(len(m), capacity=2.0, default_service_s=0.05)
    router = OptiRoute(m, StubAnalyzer(), telemetry=Telemetry(),
                       load=lt, load_weight=1.0)
    engine = ServingEngine(router)
    assert engine.load is lt                 # picked up from the router
    reqs = [Request(text=f"q{i}", prefs="accuracy-first", id=i,
                    deadline_ms=deadline_ms) for i in range(6)]
    return engine, lt, reqs


def test_serving_engine_admits_and_drains_load():
    engine, lt, reqs = _serving_setup(deadline_ms=10_000.0)
    out = engine.submit(reqs)
    assert [r.admission for r in out] == ["admitted"] * 6
    q, f, _, _ = lt.snapshot()               # lifecycle completed
    assert (q == 0).all() and (f == 0).all()
    s = engine.summary()
    assert s["admissions"] == {"admitted": 6}
    funnel = engine.router.telemetry.admission_funnel()
    assert funnel == {"admitted": 6}
    for stats in s["latency"].values():
        assert stats["p50_s"] <= stats["p99_s"]


def test_serving_engine_sheds_on_impossible_deadline():
    engine, lt, reqs = _serving_setup(deadline_ms=1e-6)
    out = engine.submit(reqs)
    assert all(r.shed for r in out)
    assert all(r.tokens is None for r in out)
    q, f, _, _ = lt.snapshot()               # shed burns no capacity
    assert (q == 0).all() and (f == 0).all()
    assert engine.summary()["admissions"] == {"shed": 6}
    assert engine.router.telemetry.admission_funnel() == {"shed": 6}


def test_serving_engine_no_deadline_unchanged():
    engine, _, reqs = _serving_setup(deadline_ms=None)
    out = engine.submit(reqs)
    assert all(r.admission == "admitted" for r in out)
    # no SLO -> nothing lands in the admission funnel
    assert engine.router.telemetry.admission_funnel() == {}


# ----------------------------------------------------------------------
# traffic scenario + simulator
# ----------------------------------------------------------------------

def test_poisson_arrivals_deterministic_and_bursty():
    sc = TrafficScenario(duration_s=10.0, base_rate=20.0,
                         burst_rate=200.0, burst_start=0.4,
                         burst_len=0.2, seed=3)
    a1, a2 = poisson_arrivals(sc), poisson_arrivals(sc)
    np.testing.assert_array_equal(a1, a2)
    assert (np.diff(a1) >= 0).all() and a1[-1] < sc.duration_s
    b0, b1 = sc.burst_window_s
    in_burst = ((a1 >= b0) & (a1 < b1)).sum() / (b1 - b0)
    outside = ((a1 < b0) | (a1 >= b1)).sum() / (sc.duration_s - (b1 - b0))
    assert in_burst > 3 * outside            # rate ratio is 10x


def test_traffic_scenario_validation():
    with pytest.raises(AssertionError):
        TrafficScenario(burst_rate=1.0, base_rate=10.0).validate()
    with pytest.raises(AssertionError):
        TrafficScenario(burst_start=0.9, burst_len=0.5).validate()


def test_simulator_single_server_math():
    """3 back-to-back arrivals on one 1s server: waits 0/1/2 s."""
    sim = ServingSimulator([1.0], [1], tracker=LoadTracker(1))
    res = sim.run(np.array([0.0, 0.0, 0.0]),
                  lambda i, t: (0, "admitted"), deadline_ms=1500.0)
    np.testing.assert_allclose(res["wait_s"], [0.0, 1.0, 2.0])
    np.testing.assert_allclose(res["latency_s"], [1.0, 2.0, 3.0])
    assert res["slo_miss"].tolist() == [False, True, True]
    assert res["slo_miss_rate"] == pytest.approx(2 / 3)


def test_simulator_parallel_servers_and_shed():
    sim = ServingSimulator([1.0, 1.0], [2, 1])
    kinds = ["admitted", "admitted", "rerouted", "shed"]
    models = [0, 0, 1, 0]
    res = sim.run(np.zeros(4),
                  lambda i, t: (models[i], kinds[i]), deadline_ms=1100.0)
    np.testing.assert_allclose(res["latency_s"][:3], [1.0, 1.0, 1.0])
    assert res["shed"].tolist() == [False, False, False, True]
    assert res["rerouted"].tolist() == [False, False, True, False]
    assert np.isnan(res["latency_s"][3])
    assert res["slo_miss"].tolist() == [False, False, False, True]


def test_simulator_mirrors_tracker_state():
    lt = LoadTracker(1, capacity=1.0, default_service_s=9.9)
    sim = ServingSimulator([0.5], [1], tracker=lt)
    seen = []

    def route(i, t):
        seen.append(lt.estimated_wait_s()[0])
        return 0, "admitted"

    sim.run(np.array([0.0, 0.1, 5.0]), route)
    # 2nd arrival sees the 1st in flight; 3rd sees a drained system
    assert seen[0] == 0.0 and seen[1] > 0.0 and seen[2] == 0.0
    q, f, _, _ = lt.snapshot()
    assert (q == 0).all() and (f == 0).all()
    # EWMA pulled toward the realized 0.5s service time
    assert lt.snapshot()[3][0] < 9.9


def test_plan_admission_sees_pending_batch_placements():
    """Request #k of one batch must see the k-1 placements planned
    ahead of it — a burst cannot be waved through (or rerouted onto a
    single alternate) against a frozen pre-batch snapshot."""
    m = _flat_catalog(3)
    lt = LoadTracker(3, capacity=1.0, default_service_s=0.1)
    col = {f"m{i}": i for i in range(3)}
    d = RoutingEngine(m, knn_k=3).route("accuracy-first", SIG)
    pending = np.zeros(3, np.int64)
    kinds = []
    # deadline fits 2 requests per model (wait+service <= 0.25s)
    for _ in range(8):
        model, kind, _ = plan_admission(d, lt, col, 250.0, pending=pending)
        kinds.append(kind)
        if kind != "shed":
            pending[col[model]] += 1
    # 3 models x 2 slots-worth of budget -> 6 placed, the rest shed
    assert kinds.count("shed") == 2
    assert pending.tolist() == [2, 2, 2]
    # without pending accounting every request would be admitted
    assert plan_admission(d, lt, col, 250.0)[1] == "admitted"


def test_serving_engine_intra_batch_admission():
    from repro.serving.engine import Request
    engine, lt, _ = _serving_setup()
    # capacity 2, service estimate 0.05s -> a 0.125s budget fits the
    # first few placements per model, then the batch must spill/shed
    reqs = [Request(text=f"q{i}", prefs="accuracy-first", id=i,
                    deadline_ms=125.0) for i in range(40)]
    out = engine.submit(reqs)
    kinds = {r.admission for r in out}
    assert "shed" in kinds, [r.admission for r in out]
    assert len({r.model for r in out if not r.shed}) > 1
    funnel = engine.router.telemetry.admission_funnel()
    assert funnel.get("shed", 0) + funnel.get("admitted", 0) \
        + funnel.get("rerouted", 0) == 40


def test_similarity_stays_pure_cosine_under_load():
    from repro.core.routing import cosine_sim
    m = _flat_catalog()
    emb = m.embeddings()
    names = m.snapshot()[1]
    lt = LoadTracker(len(m), capacity=2.0)
    lt.admit(0, count=200)
    eng = RoutingEngine(m, load=lt, load_weight=1.0)
    d = eng.route("accuracy-first", SIG)
    j = names.index(d.model)
    pure = float(cosine_sim(emb[j:j + 1], d.task_vector)[0])
    assert d.similarity == pytest.approx(pure, abs=1e-5)
    assert -1.0 - 1e-6 <= d.similarity <= 1.0 + 1e-6


class _BoomCfg:
    vocab_size = 64


class BoomRunner:
    """Test runner whose generate always raises."""
    cfg = _BoomCfg()

    def generate(self, toks, max_new=8):
        raise RuntimeError("boom")


def test_generate_failure_degrades_group_and_releases_slots():
    """A runner crash mid-batch must not leak inflight counts (which
    would permanently penalize a healthy model) — and must not
    propagate out of submit: the failed group's requests come back
    degraded (admission='failed', no tokens, no bandit handle) while
    the batch as a whole survives."""
    from repro.serving.engine import Request

    engine, lt, reqs = _serving_setup()
    routed = engine.router.route_all([r.text for r in reqs[:1]],
                                     "accuracy-first")
    boomed = routed[0].decision.model
    engine.router.mres.entry(boomed).runner = BoomRunner()
    out = engine.submit(reqs)                # must NOT raise
    q, f, _, _ = lt.snapshot()
    assert (f == 0).all() and (q == 0).all()
    assert len(out) == len(reqs)
    for r in out:
        if r.model == boomed:
            assert r.admission == "failed" and r.failed
            assert r.tokens is None and r.rq is None
            assert "boom" in r.error
        else:
            assert r.admission == "admitted"
    # the failure is visible in the funnel even without deadlines
    funnel = engine.router.telemetry.admission_funnel()
    assert funnel.get("failed", 0) == sum(r.failed for r in out) > 0
    # observe() silently skips the handle-less failed responses
    assert engine.observe([r for r in out if r.failed],
                          [1.0] * sum(r.failed for r in out)) is None


def test_failed_group_not_mislabeled_shed():
    """Requests whose ADMITTED group failed must be labeled 'failed',
    never 'shed' — they consumed slot lifecycle, and summary()'s
    admission counts must show real capacity use."""
    from repro.serving.engine import Request

    engine, lt, _ = _serving_setup()
    # a saturating deadline-carrying burst: some requests shed for
    # real, the boomed model's admitted share must stay distinct
    reqs = [Request(text=f"q{i}", prefs="accuracy-first", id=i,
                    deadline_ms=125.0) for i in range(40)]
    routed = engine.router.route_all([reqs[0].text], "accuracy-first")
    boomed = routed[0].decision.model
    engine.router.mres.entry(boomed).runner = BoomRunner()
    out = engine.submit(reqs)
    kinds = {r.admission for r in out}
    assert "failed" in kinds and "shed" in kinds
    for r in out:
        if r.model == boomed and not r.shed:
            assert r.failed
        if r.shed:         # true sheds never touched the boomed runner
            assert r.error == ""
    s = engine.summary()
    assert s["admissions"].get("failed", 0) == sum(r.failed for r in out)
    # failed requests were served by NO model: they are not in models
    assert sum(s["models"].values()) == sum(r.served for r in out)
    # final-outcome funnel still partitions the whole batch
    funnel = engine.router.telemetry.admission_funnel()
    assert sum(funnel.values()) == 40
    q, f, _, _ = lt.snapshot()
    assert (q == 0).all() and (f == 0).all()


def test_batch_mode_full_lifecycle():
    """_submit_batch must drive the same tracker lifecycle + telemetry
    as interactive mode (bugfix: batch traffic used to be invisible to
    load-aware routing and metrics)."""
    from repro.serving.engine import Request

    class ProbeRunner:
        """Asserts the tracker sees the batch in flight DURING
        generate, not just net-zero afterwards."""
        cfg = _BoomCfg()

        def __init__(self, lt, col):
            self.lt, self.col, self.seen = lt, col, -1

        def generate(self, toks, max_new=8):
            self.seen = int(self.lt.snapshot()[1][self.col])
            import types
            return types.SimpleNamespace(
                tokens=np.zeros((toks.shape[0], max_new), np.int32),
                sim_latency_s=0.01 * toks.shape[0])

    engine, lt, reqs = _serving_setup()
    tel = engine.router.telemetry
    names = engine.router.mres.snapshot()[1]
    # batch mode routes ONE aggregate decision; find it, then probe it
    decision, _, _ = engine.router.route_batch(
        [r.text for r in reqs], reqs[0].prefs)
    col = names.index(decision.model)
    probe = ProbeRunner(lt, col)
    engine.router.mres.entry(decision.model).runner = probe
    out = engine.submit(reqs, mode="batch")
    assert len({r.model for r in out}) == 1
    assert probe.seen == len(reqs)           # inflight while generating
    q, f, _, _ = lt.snapshot()
    assert (q == 0).all() and (f == 0).all() # ...and drained after
    assert lt.snapshot()[3][col] != pytest.approx(0.05)  # EWMA folded
    assert tel.summary()["events"] == len(reqs)   # one event per request
    assert all(r.sim_latency_s > 0 for r in out)


def test_batch_mode_failure_degrades_not_raises():
    from repro.serving.engine import Request
    engine, lt, reqs = _serving_setup()
    decision, _, _ = engine.router.route_batch(
        [r.text for r in reqs], reqs[0].prefs)
    engine.router.mres.entry(decision.model).runner = BoomRunner()
    out = engine.submit(reqs, mode="batch")
    assert all(r.failed and r.tokens is None for r in out)
    q, f, _, _ = lt.snapshot()
    assert (q == 0).all() and (f == 0).all()
    funnel = engine.router.telemetry.admission_funnel()
    assert funnel.get("failed", 0) == len(reqs)


def test_rerouted_and_shed_responses_carry_no_bandit_handle():
    """observe() must never credit the routed model's bandit arm with
    an outcome produced by a different model (reroute) or by no model
    (shed): those responses drop their RoutedQuery handle, and
    shed requests vanish from the per-model summary counts."""
    from repro.serving.engine import Request
    engine, lt, _ = _serving_setup()
    reqs = [Request(text=f"q{i}", prefs="accuracy-first", id=i,
                    deadline_ms=125.0) for i in range(40)]
    out = engine.submit(reqs)
    kinds = {r.admission for r in out}
    assert kinds >= {"admitted", "shed"}
    for r in out:
        if r.admission == "admitted":
            assert r.rq is not None and r.rq.decision.model == r.model
        else:
            assert r.rq is None
    # observe() silently skips handle-less responses
    assert engine.observe([r for r in out if r.shed], 
                          [1.0] * sum(r.shed for r in out)) is None
    s = engine.summary()
    assert sum(s["models"].values()) == sum(1 for r in out if not r.shed)


def test_oversized_tracker_routes_and_serves():
    """A tracker pre-sized beyond the catalog (ensure() only grows;
    trackers can be shared / provisioned ahead) must not break routing
    or admission — penalties are sliced to the catalog snapshot."""
    from repro.serving.engine import Request
    m = _flat_catalog(3)
    lt = LoadTracker(8, capacity=2.0)        # 8 arms, 3-model catalog
    lt.admit(0, count=200)
    eng = RoutingEngine(m, load=lt, load_weight=1.0)
    d = eng.route("accuracy-first", SIG)
    assert d.model != "m0"                   # penalty still applies
    from repro.core.orchestrator import OptiRoute
    from repro.serving.engine import ServingEngine
    from tests.test_routing_batch import StubAnalyzer
    router = OptiRoute(m, StubAnalyzer(), telemetry=Telemetry(),
                       load=lt, load_weight=1.0)
    engine = ServingEngine(router)
    out = engine.submit([Request(text="q", prefs="balanced", id=0,
                                 deadline_ms=60_000.0)])
    assert out[0].admission in ADMISSION_KINDS
