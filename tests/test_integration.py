"""End-to-end integration: analyzer training, routed serving over real
(reduced) JAX models, batch vs interactive modes, feedback shifting
routing, sharding spec coherence."""
import jax
import numpy as np
import pytest

from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature
from repro.data.workload import make_workload
from repro.serving.catalog import build_catalog, build_entry
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def trained_analyzer():
    an = TaskAnalyzer(AnalyzerConfig(d_model=64, n_layers=1, d_ff=128,
                                     max_len=64))
    metrics = an.train(n_samples=768, steps=90, batch_size=96)
    assert metrics["task_type_acc"] > 0.8
    assert metrics["domain_acc"] > 0.8
    assert metrics["complexity_mae"] < 0.2
    return an


@pytest.fixture(scope="module")
def catalog3():
    """3 runnable reduced archs spanning families (dense, moe, ssm)."""
    return build_catalog(smoke_runners=True,
                         archs=["llama3.2-1b", "qwen3-moe-30b-a3b",
                                "mamba2-1.3b"])


def test_routed_serving_end_to_end(trained_analyzer, catalog3):
    router = OptiRoute(catalog3, trained_analyzer)
    eng = ServingEngine(router)
    wl = make_workload(8, seed=11)
    resps = eng.submit([Request(text=r.text, prefs="balanced", id=r.id,
                                max_new=3) for r in wl])
    assert len(resps) == 8
    for r in resps:
        assert r.model in {e.name for e in catalog3.entries}
        assert r.tokens is not None and r.tokens.shape == (3,)
        assert r.sim_latency_s > 0
    s = eng.summary()
    assert s["requests"] == 8 and sum(s["models"].values()) == 8


def test_batch_mode_single_model(trained_analyzer, catalog3):
    router = OptiRoute(catalog3, trained_analyzer, batch_sample_frac=0.1)
    eng = ServingEngine(router)
    wl = make_workload(30, seed=12, task_type="summarization",
                       domain="general")
    resps = eng.submit([Request(text=r.text, prefs="cost-effective",
                                max_new=2) for r in wl], mode="batch")
    assert len({r.model for r in resps}) == 1       # one model, whole batch
    # batch mode analyzed only the ~10% sample, not every query
    # (structural check — wall-time is flaky under CPU contention)
    decision, sigs, stats = router.route_batch([r.text for r in wl],
                                               "cost-effective")
    assert stats["sampled"] <= max(3, len(wl) // 5)


def test_feedback_shifts_routing(trained_analyzer, catalog3):
    # feedback_weight scaled to the score range (sum of 8 weights)
    router = OptiRoute(catalog3, trained_analyzer, feedback_weight=3.0)
    text = make_workload(1, seed=13, task_type="chat",
                         domain="general")[0].text
    rq1 = router.route(text, "balanced")
    # hammer the chosen model with thumbs-down for this cluster
    for _ in range(12):
        router.give_feedback(rq1, thumbs_up=False)
    rq2 = router.route(text, "balanced")
    assert rq2.decision.model != rq1.decision.model
    assert rq2.decision.score < rq1.decision.score + 1e-6


def test_merging_fallback_in_orchestrator(trained_analyzer):
    """The soup fires when the strong same-family parent was excluded
    by the domain filter: the merged entry inherits the union of the
    parents' domains and outscores the weak in-domain incumbent.

    (With linear min-max normalization a soup can never strictly beat
    the best UNFILTERED parent — the score is linear in alpha — so the
    filtered-parent scenario is exactly where §5 merging pays off.)"""
    from repro.core.mres import MRES
    from tests.conftest import make_entry
    mres = MRES()
    mres.register(make_entry("legal-weak", accuracy=0.4, latency_ms=50,
                             cost=1.0, family="dense", n_params=100,
                             task_types=("summarization",),
                             domains=("legal",)))
    mres.register(make_entry("general-strong", accuracy=0.95, latency_ms=40,
                             cost=1.0, family="dense", n_params=100,
                             task_types=("summarization",),
                             domains=("general",)))
    router = OptiRoute(mres, trained_analyzer, merge_threshold=10.0)
    text = make_workload(1, seed=14, task_type="summarization",
                         domain="legal")[0].text
    rq = router.route(text, "balanced")
    soups = [e for e in mres.entries if e.name.startswith("soup:")]
    assert soups, "merger did not fire"
    assert rq.decision.model == soups[0].name   # soup won the re-route
    assert "legal" in soups[0].domains and "general" in soups[0].domains


def test_interactive_groups_identical_models(trained_analyzer, catalog3):
    router = OptiRoute(catalog3, trained_analyzer)
    eng = ServingEngine(router)
    wl = make_workload(6, seed=15, task_type="code", domain="software",
                       complexity=0.9)
    calls_before = {e.name: e.runner.stats.get("calls", 0)
                    for e in catalog3.entries}
    resps = eng.submit([Request(text=r.text, prefs="accuracy-first",
                                max_new=2) for r in wl])
    # requests routed to the same model share ONE batched generate call
    models = {r.model for r in resps}
    new_calls = sum(e.runner.stats.get("calls", 0) - calls_before[e.name]
                    for e in catalog3.entries)
    assert new_calls == len(models) <= 2


def test_catalog_entries_have_roofline_metrics():
    e = build_entry("qwen2-1.5b")
    assert e.raw_metrics["latency_ms"] > 0
    assert e.raw_metrics["cost_per_mtok"] > 0
    assert e.meta["active_params"] > 1e8
