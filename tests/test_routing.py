"""Routing Engine + MRES behaviour tests (paper §3.3/§3.4)."""
import numpy as np
import pytest

from repro.core.mres import MRES, normalize_catalog
from repro.core.preferences import (METRICS, PROFILES, TaskSignature,
                                    UserPreferences, resolve)
from repro.core.routing import RoutingEngine
from tests.conftest import make_entry


def test_normalization_range_and_inversion(small_mres):
    emb = small_mres.embeddings()
    assert emb.shape == (4, len(METRICS))
    assert (emb >= 0).all() and (emb <= 1).all()
    # latency is inverted into speed: fastest model gets 1
    names = [e.name for e in small_mres.entries]
    speed = emb[:, METRICS.index("speed")]
    assert names[int(np.argmax(speed))] == "tiny-fast"
    cheap = emb[:, METRICS.index("cheapness")]
    assert names[int(np.argmax(cheap))] == "tiny-fast"
    acc = emb[:, METRICS.index("accuracy")]
    assert names[int(np.argmax(acc))] == "big-accurate"


def test_normalization_scale_invariance(small_mres):
    emb1 = small_mres.embeddings()
    # multiply a raw metric column by a constant
    for e in small_mres.entries:
        small_mres.update_metrics(e.name,
                                  latency_ms=e.raw_metrics["latency_ms"] * 37.0)
    emb2 = small_mres.embeddings()
    np.testing.assert_allclose(emb1, emb2, rtol=1e-6, atol=1e-6)


def test_duplicate_registration_rejected(small_mres):
    with pytest.raises(ValueError):
        small_mres.register(make_entry("mid"))


def test_update_metrics_refreshes_all_caches(small_mres):
    """Regression: updating a raw metric must invalidate EVERY derived
    cache (embeddings, fused routing matrix, mask matrices) so the
    next route_many sees the new values — not a stale snapshot."""
    snap1 = small_mres.snapshot()
    eng = RoutingEngine(small_mres)
    sig = TaskSignature(task_type="chat", domain="general", complexity=0.3)
    d1 = eng.route("accuracy-first", sig)
    assert d1.model == "big-accurate"
    # tank the old winner's accuracy AND helpfulness; the cheap model
    # becomes the leader.  (With accuracy alone the two blended scores
    # land on an EXACT real-arithmetic tie — 1.0+0.2+0.1+0.3+0.3 vs
    # 0.7+0.6+0.3+0.3 — whose winner would be decided by f32 rounding
    # order, i.e. by the scoring backend, not by the catalog refresh
    # this test is about.)
    small_mres.update_metrics("big-accurate", accuracy=0.01,
                              helpfulness=0.4)
    small_mres.update_metrics("tiny-fast", accuracy=0.99)
    snap2 = small_mres.snapshot()
    assert snap2[0] is not snap1[0]           # embeddings rebuilt
    assert snap2[5] is not snap1[5]           # fused routing matrix rebuilt
    assert not np.allclose(snap2[0], snap1[0])
    names = snap2[1]
    acc = snap2[0][:, METRICS.index("accuracy")]
    assert names[int(np.argmax(acc))] == "tiny-fast"
    # the fused routing matrix's metric block tracks the new embeddings
    en = np.linalg.norm(snap2[0], axis=1, keepdims=True) + 1e-9
    np.testing.assert_allclose(snap2[5][:, :len(METRICS)],
                               snap2[0] / en, rtol=1e-5, atol=1e-6)
    d2 = eng.route("accuracy-first", sig)
    assert d2.model != "big-accurate"


def test_update_metrics_refresh_under_concurrent_readers(small_mres):
    """Writers flip the dirty flag while reader threads snapshot —
    every snapshot must be internally consistent (all-old or all-new),
    never a torn mix."""
    import threading
    small_mres.snapshot()
    stop = threading.Event()
    errs = []

    def reader():
        try:
            while not stop.is_set():
                emb, names, *_, mat = small_mres.snapshot()
                en = np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
                np.testing.assert_allclose(mat[:, :len(METRICS)],
                                           emb / en, rtol=1e-5, atol=1e-6)
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for i in range(200):
        small_mres.update_metrics("mid", accuracy=0.1 + (i % 9) / 10.0)
    stop.set()
    for t in threads:
        t.join()
    assert not errs


def test_route_prefers_cheap_for_cost_profile(small_mres):
    eng = RoutingEngine(small_mres)
    sig = TaskSignature(task_type="chat", domain="general", complexity=0.1)
    d = eng.route("cost-effective", sig)
    assert d.model in ("tiny-fast", "mid")    # never the expensive one
    # with cheapness as the only priority the cheapest model must win
    d2 = eng.route({"cheapness": 1.0, "speed": 0.0, "accuracy": 0.0,
                    "helpfulness": 0.0, "harmlessness": 0.0, "honesty": 0.0,
                    "steerability": 0.0, "creativity": 0.0}, sig)
    assert d2.model == "tiny-fast"


def test_route_prefers_accurate_for_hard_tasks(small_mres):
    eng = RoutingEngine(small_mres)
    sig = TaskSignature(task_type="reasoning", domain="general",
                        complexity=0.95)
    d = eng.route("accuracy-first", sig)
    assert d.model == "big-accurate"


def test_hierarchical_filter_domain(small_mres):
    eng = RoutingEngine(small_mres)
    sig = TaskSignature(task_type="summarization", domain="legal",
                        complexity=0.5)
    d = eng.route("balanced", sig)
    entry = small_mres.entry(d.model)
    assert "legal" in entry.domains


def test_fallback_to_generalist(small_mres):
    """A task type no model supports must fall back, never crash."""
    eng = RoutingEngine(small_mres)
    sig = TaskSignature(task_type="vqa", domain="healthcare", complexity=0.5)
    d = eng.route("balanced", sig)
    assert d.used_fallback and d.model
    assert small_mres.entry(d.model).generalist


def test_low_confidence_skips_filters(small_mres):
    eng = RoutingEngine(small_mres, confidence_threshold=0.5)
    sig = TaskSignature(task_type="vqa", domain="healthcare",
                        complexity=0.5, confidence=0.1)
    d = eng.route("balanced", sig)
    assert not d.used_fallback   # filters were skipped, kNN set survives


def test_complexity_raises_accuracy_demand(small_mres):
    eng = RoutingEngine(small_mres)
    prefs = UserPreferences(weights={m: 0.3 for m in METRICS})
    easy = eng.task_vector(prefs, TaskSignature(complexity=0.1))
    hard = eng.task_vector(prefs, TaskSignature(complexity=0.9))
    iacc = METRICS.index("accuracy")
    assert hard[iacc] > easy[iacc]
    assert hard[iacc] == pytest.approx(0.9)


def test_kernel_and_numpy_knn_agree(small_mres):
    """use_kernel=True must route identically to the numpy path."""
    rng = np.random.default_rng(0)
    m = MRES()
    for i in range(64):
        m.register(make_entry(
            f"m{i}", accuracy=float(rng.random()),
            latency_ms=float(rng.random() * 100 + 1),
            cost=float(rng.random() * 10 + 0.1),
            helpfulness=float(rng.random()),
            task_types=("chat",), generalist=True))
    sig = TaskSignature(task_type="chat", complexity=0.4)
    d_np = RoutingEngine(m, knn_k=8, use_kernel=False).route("balanced", sig)
    eng_k = RoutingEngine(m, knn_k=8, use_kernel=True)
    eng_k._kernel_min_n = 0
    d_k = eng_k.route("balanced", sig)
    assert d_np.model == d_k.model


def test_profiles_resolve():
    for name in PROFILES:
        p = resolve(name)
        assert p.validate() is p
    with pytest.raises(KeyError):
        resolve("no-such-profile")
    p = resolve({"accuracy": 0.9})
    assert p.vector()[METRICS.index("accuracy")] == pytest.approx(0.9)
