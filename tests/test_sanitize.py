"""Runtime-sanitizer tests: the lock-order cycle detector fires on a
seeded ABBA inversion (and stays silent on consistent ordering), and
the recompile sentinel fires on a deliberately cleared jit cache (and
stays silent on warm steady-state dispatch)."""
import threading

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.analysis.sanitize import (LockOrderError, OrderedLock,
                                     RecompileSentinel, make_lock)
from repro.core.preferences import DOMAINS, METRICS, TASK_TYPES
from repro.kernels import ops
from repro.kernels.route_step import route_step_jit


@pytest.fixture
def clean_lock_graph():
    """Isolate the global lock-order graph: tests here seed deliberate
    inversions that must not leak into (or inherit from) the suite's
    real lock edges."""
    sanitize.reset_lock_order()
    yield
    sanitize.reset_lock_order()


# ---------------------------------------------------------------------
# lock-order detector
# ---------------------------------------------------------------------

def test_abba_cycle_fires(clean_lock_graph):
    a, b = OrderedLock("t.A"), OrderedLock("t.B")
    with a:
        with b:                 # establishes A -> B
            pass
    with pytest.raises(LockOrderError, match="t.A"):
        with b:
            with a:             # B -> A closes the cycle
                pass
    assert sanitize.lock_order_violations(), \
        "violation must be recorded for post-mortem reporting"


def test_abba_cycle_fires_across_threads(clean_lock_graph):
    """The graph is global: thread 1 establishes A -> B, thread 2's
    B -> A acquisition is refused deterministically — no unlucky
    interleaving needed."""
    a, b = OrderedLock("x.A"), OrderedLock("x.B")
    errors = []

    def t1():
        with a:
            with b:
                pass

    def t2():
        try:
            with b:
                with a:
                    pass
        except LockOrderError as e:
            errors.append(e)

    th1 = threading.Thread(target=t1)
    th1.start()
    th1.join()
    th2 = threading.Thread(target=t2)
    th2.start()
    th2.join()
    assert len(errors) == 1
    assert sanitize.lock_order_violations()[-1][:2] == ("x.B", "x.A")


def test_consistent_order_is_silent(clean_lock_graph):
    a, b, c = (OrderedLock(n) for n in ("s.A", "s.B", "s.C"))
    for _ in range(3):
        with a:
            with b:
                with c:
                    pass
    with b:                     # partial chains in the same order: fine
        with c:
            pass
    assert sanitize.lock_order_violations() == []
    graph = sanitize.lock_order_graph()
    assert "s.B" in graph["s.A"] and "s.C" in graph["s.B"]


def test_transitive_cycle_detected(clean_lock_graph):
    a, b, c = (OrderedLock(n) for n in ("v.A", "v.B", "v.C"))
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with pytest.raises(LockOrderError):
        with c:
            with a:             # A ->* C exists, C -> A closes it
                pass


def test_same_name_nesting_skipped(clean_lock_graph):
    # two instances of the same component (same role name) locked
    # nested — instance-level ordering is out of scope for a name graph
    l1, l2 = OrderedLock("dup"), OrderedLock("dup")
    with l1:
        with l2:
            pass
    with l2:
        with l1:
            pass
    assert sanitize.lock_order_violations() == []


def test_make_lock_honors_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not isinstance(make_lock("m"), OrderedLock)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    lk = make_lock("m")
    assert isinstance(lk, OrderedLock)
    with lk:                    # context-manager protocol works
        assert lk.locked()
    assert not lk.locked()


# ---------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------

def _event(compiles, path="dense", q=8, n=256):
    return {"path": path, "q_bucket": q, "n_bucket": n, "quant": "f32",
            "shards": 1, "compiles": compiles}


def test_sentinel_warmup_then_steady_state_silent():
    s = RecompileSentinel()
    s(_event(1))                # first compile per signature: warmup
    s(_event(0))
    s(_event(0))
    s(_event(1, n=512))         # new bucket: its own warmup
    assert s.drain() == []


def test_sentinel_fires_on_post_warmup_compile():
    s = RecompileSentinel()
    s(_event(1))
    s(_event(1))                # same signature compiles again
    viols = s.drain()
    assert len(viols) == 1 and "n_bucket=256" in viols[0]
    assert s.drain() == []      # drain clears
    s.forget()
    s(_event(1))                # after forget, warmup restarts
    assert s.drain() == []


def _tiny_problem(seed=0):
    rng = np.random.default_rng(seed)
    B, N, M = 2, 8, len(METRICS)
    nt, nd = len(TASK_TYPES), len(DOMAINS)
    emb = rng.random((N, M)).astype(np.float32)
    tt = np.ones((nt + 1, N), bool)
    dm = np.ones((nd + 1, N), bool)
    gmask = np.zeros(N, bool)
    T = rng.random((B, M)).astype(np.float32)
    W = rng.random((B, M)).astype(np.float32)
    ti = np.zeros(B, np.int32)
    di = np.zeros(B, np.int32)
    return emb, tt, dm, gmask, T, W, ti, di


def test_sentinel_end_to_end_on_route_step():
    """Installed on the real dispatcher: warm dispatches are silent; a
    deliberately cleared jit cache (the seeded breakage) trips it."""
    args = _tiny_problem()
    prev_hook = ops._RECOMPILE_HOOK
    s = RecompileSentinel().install()
    try:
        ops.route_step(*args, k=3, r=3)        # warmup (compile or cached)
        ops.route_step(*args, k=3, r=3)        # steady state
        assert s.drain() == []
        route_step_jit._clear_cache()        # deliberate breakage
        ops.route_step(*args, k=3, r=3)        # recompiles a seen bucket
        viols = s.drain()
        assert viols and "after warmup" in viols[0]
    finally:
        ops.set_recompile_hook(prev_hook)


def test_set_recompile_hook_detach():
    events = []
    prev_hook = ops._RECOMPILE_HOOK
    ops.set_recompile_hook(events.append)
    try:
        ops.route_step(*_tiny_problem(1), k=2, r=2)
        assert len(events) == 1
        ev = events[0]
        assert set(ev) == {"path", "q_bucket", "n_bucket", "quant",
                           "shards", "compiles"}
        assert ev["path"] == "dense"
        ops.set_recompile_hook(None)
        ops.route_step(*_tiny_problem(1), k=2, r=2)
        assert len(events) == 1              # detached: no more events
    finally:
        ops.set_recompile_hook(prev_hook)
