"""Unit tests: model building blocks vs hand-computed / jnp oracles."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.kernels import ref as R
from repro.models import layers as L

RNG = np.random.default_rng(7)


def test_rms_norm_matches_manual():
    x = jnp.asarray(RNG.standard_normal((2, 5, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(8), jnp.float32)
    got = L.rms_norm(x, w)
    want = x / np.sqrt(np.mean(np.square(np.asarray(x)), -1, keepdims=True)
                       + 1e-6) * (1 + np.asarray(w))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_softcap_limits_and_identity():
    x = jnp.asarray([-1e4, -1.0, 0.0, 1.0, 1e4])
    y = np.asarray(L.softcap(x, 30.0))
    assert (np.abs(y) <= 30.0 + 1e-6).all()
    np.testing.assert_allclose(y[2], 0.0)
    assert np.asarray(L.softcap(x, 0.0) is x or
                      np.allclose(np.asarray(L.softcap(x, 0.0)), np.asarray(x)))


def test_rope_preserves_norm_and_relative_angle():
    B, Lq, H, hd = 1, 6, 2, 8
    x = jnp.asarray(RNG.standard_normal((B, Lq, H, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(Lq, dtype=jnp.int32)[None], (B, Lq))
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i-j: rotate both by a shift
    q = jnp.asarray(RNG.standard_normal((B, Lq, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Lq, H, hd)), jnp.float32)
    d1 = np.einsum("blhd,bmhd->bhlm",
                   np.asarray(L.apply_rope(q, pos, 1e4)),
                   np.asarray(L.apply_rope(k, pos, 1e4)))
    d2 = np.einsum("blhd,bmhd->bhlm",
                   np.asarray(L.apply_rope(q, pos + 13, 1e4)),
                   np.asarray(L.apply_rope(k, pos + 13, 1e4)))
    np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-3)


def _tiny_cfg(**kw):
    base = dict(name="t", arch_type="dense", n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base).validate()


def test_attention_full_matches_ref_kernel_oracle():
    cfg = _tiny_cfg()
    p = L.init_attention(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((2, 10, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(10, dtype=jnp.int32)[None], (2, 10))
    out, (k, v) = L.attention_full(p, cfg, x, pos)
    q = L._split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    want = R.mha_attention(q, k, v, causal=True)
    want = want.reshape(2, 10, cfg.q_dim) @ p["wo"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_block_no_drop_equals_dense_mixture():
    """With capacity >= group size, MoE output == explicit per-token sum."""
    cfg = _tiny_cfg(arch_type="moe", n_experts=4, moe_top_k=2,
                    moe_capacity_factor=4.0, moe_group=8)
    p = L.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, 32)), jnp.float32)
    y, aux = L.moe_block(p, cfg, x)
    # oracle: route each token to its top-k experts with renorm weights
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    vals, idx = jax.lax.top_k(probs, 2)
    vals = vals / vals.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x))
    for b in range(2):
        for t in range(8):
            for j in range(2):
                e = int(idx[b, t, j])
                xe = np.asarray(x[b, t])
                h = (jax.nn.silu(xe @ p["wg"][e]) * (xe @ p["wi"][e]))
                want[b, t] += float(vals[b, t, j]) * np.asarray(h @ p["wo"][e])
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 1.0 - 1e-3   # load-balance loss lower bound is 1


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflow tokens contribute zero (residual)."""
    cfg = _tiny_cfg(arch_type="moe", n_experts=2, moe_top_k=1,
                    moe_capacity_factor=0.25, moe_group=8)
    p = L.init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(RNG.standard_normal((1, 8, 32)), jnp.float32)
    y, _ = L.moe_block(p, cfg, x)
    # capacity C = ceil(8 * 1 / 2 * 0.25) = 1 per expert => <= 2 tokens kept
    nonzero = np.abs(np.asarray(y)).sum(-1) > 1e-6
    assert nonzero.sum() <= 2


def test_causal_conv_matches_numpy():
    w = jnp.asarray(RNG.standard_normal((4, 6)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 10, 6)), jnp.float32)
    y, state = L._causal_conv(x, w)
    xp = np.concatenate([np.zeros((2, 3, 6), np.float32), np.asarray(x)], 1)
    want = sum(xp[:, i:i + 10] * np.asarray(w)[i] for i in range(4))
    want = np.asarray(jax.nn.silu(jnp.asarray(want)))
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), xp[:, -3:], rtol=1e-6)


def test_ssd_chunked_matches_sequential_ref():
    cfg = get_smoke("mamba2-1.3b")
    p = L.init_ssm(jax.random.PRNGKey(3), cfg)
    u = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)) * 0.1,
                    jnp.float32)
    y, hf, conv = L.ssd_chunked(p, cfg, u)
    # oracle path: same splits, sequential scan via kernels/ref.py
    z, xBC, dt = L._ssm_split(p, cfg, u)
    xBC, _ = L._causal_conv(xBC, p["conv_w"])
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x = xBC[..., :di].reshape(2, 32, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y_ref, h_ref = R.ssd_scan(x, dt, A, Bm, Cm)
    y_ref = y_ref + np.asarray(x) * np.asarray(p["D"])[None, None, :, None]
    y_ref = jnp.asarray(y_ref.reshape(2, 32, di), jnp.float32)
    y_ref = L.rms_norm(y_ref * jax.nn.silu(z), p["norm"]) @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


def test_gqa_scores_and_values_shapes():
    q = jnp.asarray(RNG.standard_normal((2, 5, 8, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 7, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 7, 2, 16)), jnp.float32)
    s = L.gqa_scores(q, k)
    assert s.shape == (2, 2, 4, 5, 7)
    out = L.gqa_values(jax.nn.softmax(s, -1), v)
    assert out.shape == (2, 5, 8, 16)
