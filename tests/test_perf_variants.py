"""Correctness of the §Perf variants (EXPERIMENTS.md):

  * blocked attention == naive attention (all families, banded + full,
    local/global alternation, long mode),
  * int8 KV-cache decode stays close to the fp decode,
  * moe_shard_axis variants produce identical math (specs only differ).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M

RNG = np.random.default_rng(3)

ATTN_ARCHS = ["llama3.2-1b", "hymba-1.5b", "gemma2-2b", "h2o-danube-3-4b",
              "qwen2-1.5b", "qwen3-moe-30b-a3b"]


@pytest.mark.parametrize("arch", ATTN_ARCHS)
@pytest.mark.parametrize("blk", [32, 64])
def test_blocked_equals_naive(arch, blk):
    smoke = get_smoke(arch)
    cfg_b = dataclasses.replace(smoke, attn_impl="blocked", attn_block_q=blk)
    cfg_n = dataclasses.replace(smoke, attn_impl="naive")
    params = M.init_params(jax.random.PRNGKey(0), cfg_b)
    L = 150          # non multiple of blk; > smoke window (64)
    b = {"tokens": jnp.asarray(RNG.integers(0, smoke.vocab_size, (2, L)),
                               jnp.int32)}
    lb, _, _ = M.forward_full(params, cfg_b, b)
    ln, _, _ = M.forward_full(params, cfg_n, b)
    err = float(jnp.max(jnp.abs(lb - ln)))
    assert err < 2e-3, (arch, blk, err)


def test_blocked_equals_naive_long_mode():
    smoke = get_smoke("gemma2-2b")
    kw = dict(long_mode_local_only=True)
    cfg_b = dataclasses.replace(smoke, attn_impl="blocked",
                                attn_block_q=32, **kw)
    cfg_n = dataclasses.replace(smoke, attn_impl="naive", **kw)
    params = M.init_params(jax.random.PRNGKey(0), cfg_b)
    b = {"tokens": jnp.asarray(RNG.integers(0, smoke.vocab_size, (1, 100)),
                               jnp.int32)}
    lb, _, _ = M.forward_full(params, cfg_b, b, long_mode=True)
    ln, _, _ = M.forward_full(params, cfg_n, b, long_mode=True)
    assert float(jnp.max(jnp.abs(lb - ln))) < 2e-3


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b",
                                  "h2o-danube-3-4b", "qwen3-moe-30b-a3b"])
def test_int8_kv_cache_decode_close(arch):
    smoke = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(1), smoke)
    b = {"tokens": jnp.asarray(RNG.integers(0, smoke.vocab_size, (2, 24)),
                               jnp.int32)}
    logits, _, _ = M.forward_full(params, smoke, b)
    cfg8 = dataclasses.replace(smoke, kv_cache_dtype="int8")
    _, cache, pos = M.prefill(params, cfg8, {"tokens": b["tokens"][:, :-1]})
    assert cache["k"].dtype == jnp.int8
    assert "k_scale" in cache
    dl, new_cache = M.decode_step(params, cfg8, cache,
                                  {"token": b["tokens"][:, -1:], "pos": pos})
    err = float(jnp.max(jnp.abs(dl - logits[:, -1])))
    assert err < 0.5, (arch, err)
    assert new_cache["k"].dtype == jnp.int8


def test_int8_ring_buffer_prefill():
    """SWA ring-buffer cache also supports int8 (roll path)."""
    smoke = get_smoke("h2o-danube-3-4b")
    cfg8 = dataclasses.replace(smoke, kv_cache_dtype="int8")
    params = M.init_params(jax.random.PRNGKey(2), cfg8)
    L = 100                                  # > smoke window 64 -> ring
    toks = jnp.asarray(RNG.integers(0, cfg8.vocab_size, (1, L)), jnp.int32)
    last, cache, pos = M.prefill(params, cfg8, {"tokens": toks})
    assert cache["k"].shape[2] == smoke.sliding_window
    dl, _ = M.decode_step(params, cfg8, cache,
                          {"token": toks[:, -1:], "pos": pos})
    assert not bool(jnp.isnan(dl).any())


def test_long_serving_window_ring_decode():
    """Beyond-paper long-serving mode (DESIGN §4): a full-attention
    arch degrades to an SWA ring cache at long contexts; decode against
    the ring cache matches the full forward under the effective SWA
    config exactly."""
    smoke = get_smoke("llama3.2-1b")
    plain = dataclasses.replace(smoke, long_serving_window=0)
    assert not plain.subquadratic          # full attention refuses 500k
    cfg = dataclasses.replace(smoke, long_serving_window=32)
    assert cfg.subquadratic
    eff = cfg.long_serving_config()
    assert eff.sliding_window == 32
    assert eff.n_params() == cfg.n_params()      # params unchanged
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    L = 80                                        # > window -> ring wraps
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, L)), jnp.int32)
    logits_ref, _, _ = M.forward_full(params, eff, {"tokens": toks})
    _, cache, pos = M.prefill(params, eff, {"tokens": toks[:, :-1]})
    assert cache["k"].shape[2] == 32
    dl, _ = M.decode_step(params, eff, cache,
                          {"token": toks[:, -1:], "pos": pos})
    assert float(jnp.max(jnp.abs(dl - logits_ref[:, -1]))) < 5e-2
    # archs that are already sub-quadratic are untouched
    mamba = get_smoke("mamba2-1.3b")
    assert mamba.long_serving_config() is mamba


def test_moe_shard_axis_is_spec_only():
    """'f' vs 'd' expert sharding changes PartitionSpecs, not math."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules as R
    cfg_f = get_smoke("qwen3-moe-30b-a3b")
    cfg_d = dataclasses.replace(cfg_f, moe_shard_axis="d")
    params = M.init_params(jax.random.PRNGKey(0), cfg_f)
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg_f.vocab_size, (2, 16)),
                               jnp.int32)}
    lf, _, _ = M.forward_full(params, cfg_f, b)
    ld, _, _ = M.forward_full(params, cfg_d, b)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ld))
    mesh = make_host_mesh()
    sf = R.param_specs(cfg_f, mesh, params)
    sd = R.param_specs(cfg_d, mesh, params)
    assert jax.tree_util.tree_structure(sf) == \
        jax.tree_util.tree_structure(sd)
