"""Continuous-batching scheduler tests: slot reuse, correctness vs
sequential generation, no starvation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.serving.scheduler import ContinuousBatcher, SlotRequest

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("llama3.2-1b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _sequential(cfg, params, tokens, max_new):
    """Oracle: single-sequence greedy generation."""
    toks = jnp.asarray(tokens[None], jnp.int32)
    last, cache, pos = M.prefill(params, cfg, {"tokens": toks},
                                 max_len=256)
    out = [int(jnp.argmax(last[0]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(max_new - 1):
        logits, cache = M.decode_step(params, cfg, cache,
                                      {"token": tok, "pos": pos})
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
        pos = pos + 1
    return out


def test_matches_sequential_generation(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=3, ctx_len=64)
    prompts = [RNG.integers(2, cfg.vocab_size, L).astype(np.int32)
               for L in (7, 12, 9, 15, 5)]
    for i, p in enumerate(prompts):
        cb.submit(SlotRequest(id=i, tokens=p, max_new=4))
    finished = cb.run_until_drained()
    assert len(finished) == 5
    by_id = {r.id: r for r in finished}
    for i, p in enumerate(prompts):
        want = _sequential(cfg, params, p, 4)
        assert by_id[i].out == want, (i, by_id[i].out, want)


def test_slots_are_reused(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=64)
    for i in range(6):                      # 6 requests through 2 slots
        cb.submit(SlotRequest(
            id=i, tokens=RNG.integers(2, cfg.vocab_size, 6).astype(np.int32),
            max_new=2 + (i % 3)))
    finished = cb.run_until_drained()
    assert len(finished) == 6
    assert {r.slot for r in finished} <= {0, 1}
    # mixed max_new: short requests must not have waited for long ones
    assert cb.ticks < sum(2 + (i % 3) for i in range(6))


def test_drains_empty_queue(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=32)
    assert cb.run_until_drained() == []
    assert cb.tick() == 0


def test_submit_rejects_overflowing_prompt(setup):
    """Prompts that would run the decode position off the slot cache
    (silent OOB .at[].set KV drops) must be refused at submit time."""
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=16)
    for bad_len in (16, 17, 40):
        with pytest.raises(ValueError, match="ctx_len"):
            cb.submit(SlotRequest(
                id=0, tokens=RNG.integers(2, cfg.vocab_size,
                                          bad_len).astype(np.int32),
                max_new=2))
    assert not cb.queue                  # nothing was enqueued
    # the boundary case: len + max_new - 1 == ctx_len is admissible
    cb.submit(SlotRequest(
        id=1, tokens=RNG.integers(2, cfg.vocab_size, 15).astype(np.int32),
        max_new=2))
    assert len(cb.queue) == 1


def test_submit_truncate_clips_and_generates(setup):
    cfg, params = setup
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=16)
    long = RNG.integers(2, cfg.vocab_size, 48).astype(np.int32)
    req = SlotRequest(id=0, tokens=long, max_new=3)
    cb.submit(req, truncate=True)
    assert len(req.tokens) == 14         # clipped to ctx_len - max_new + 1
    np.testing.assert_array_equal(req.tokens, long[:14])
    finished = cb.run_until_drained()
    assert len(finished) == 1 and len(finished[0].out) == 3
    # truncated prompt == natively-short prompt (same decode result)
    want = _sequential(cfg, params, long[:14], 3)
    assert finished[0].out == want


def test_batcher_mirrors_load_tracker(setup):
    """The batcher reports queue depth, slot occupancy and realized
    service time into its LoadTracker arm as requests move through."""
    from repro.serving.load import LoadTracker
    cfg, params = setup
    lt = LoadTracker(default_service_s=99.0)
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=64,
                           load=lt, model_idx=1)
    assert lt.n_models == 2 and lt.snapshot()[2][1] == 2.0  # capacity=slots
    for i in range(4):
        cb.submit(SlotRequest(
            id=i, tokens=RNG.integers(2, cfg.vocab_size, 6).astype(np.int32),
            max_new=2))
    q, f, _, _ = lt.snapshot()
    assert q[1] == 4 and f[1] == 0
    assert cb.queue_depth() == 4
    cb.tick()                            # 2 admitted into slots
    q, f, _, _ = lt.snapshot()
    assert q[1] == 2 and f[1] == 2
    cb.run_until_drained()
    q, f, _, ewma = lt.snapshot()
    assert q[1] == 0 and f[1] == 0 and cb.queue_depth() == 0
    assert ewma[1] < 99.0                # realized service times folded in


def test_max_ticks_exit_rolls_tracker_back(setup):
    """Abandoning the backlog at max_ticks must roll the mirrored
    tracker arm back to zero — a stuck scheduler must not leave its
    model permanently penalized (bugfix: counters used to stay
    inflated forever)."""
    from repro.serving.load import LoadTracker
    cfg, params = setup
    lt = LoadTracker(default_service_s=0.5)
    cb = ContinuousBatcher(cfg, params, slots=2, ctx_len=64,
                           load=lt, model_idx=0)
    for i in range(5):
        cb.submit(SlotRequest(
            id=i, tokens=RNG.integers(2, cfg.vocab_size, 6).astype(np.int32),
            max_new=8))
    finished = cb.run_until_drained(max_ticks=2)   # nowhere near done
    assert len(finished) < 5 and cb.queue_depth() == 0
    q, f, _, ewma = lt.snapshot()
    assert q[0] == 0 and f[0] == 0
    assert ewma[0] == pytest.approx(0.5)  # cancel folds NO ewma sample
    assert len(cb.cancelled) == 5 - len(finished)
    assert all(r.slot == -1 for r in cb.cancelled)
    # opting out keeps the backlog (and its tracker counters) intact
    cb2 = ContinuousBatcher(cfg, params, slots=2, ctx_len=64,
                            load=lt, model_idx=1)
    cb2.submit(SlotRequest(
        id=9, tokens=RNG.integers(2, cfg.vocab_size, 6).astype(np.int32),
        max_new=50))
    cb2.run_until_drained(max_ticks=cb2.ticks + 1,
                          cancel_leftover=False)
    assert cb2.queue_depth() == 1
    assert lt.snapshot()[1][1] == 1      # still inflight, by request
    cb2.cancel()                         # explicit drain path
    assert cb2.queue_depth() == 0
    q, f, _, _ = lt.snapshot()
    assert q[1] == 0 and f[1] == 0
