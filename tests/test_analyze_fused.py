"""Fused analyze->route path: kernel/oracle parity, fused-vs-staged
differential (single source of truth: ``analyze_batch`` +
``route_many``), vectorized tokenizer/pruning equivalence, empty-batch
and B=1 bucket-reuse regressions, and the one-dispatch / zero-recompile
guards for the tokens->decision program."""
import numpy as np
import pytest

import jax

from repro.core.analyzer import (AnalyzerConfig, TaskAnalyzer,
                                 init_analyzer, prune_text, prune_texts,
                                 quantize_int8)
from repro.core.feedback import FeedbackStore
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import DOMAINS, TASK_TYPES, UserPreferences
from repro.core.routing import RoutingEngine
from repro.data.tokenizer import PAD_ID, HashTokenizer
from repro.kernels import ops as K
from repro.kernels import ref as R
from tests.test_routing_batch import StubAnalyzer, random_catalog

# small config: fast to init, and a distinct max_len per size so two
# differently-shaped analyzers never share a recompile-sentinel
# signature (the sentinel keys on the token axis, not d_model)
CFG = AnalyzerConfig(vocab_size=512, d_model=32, n_layers=1, n_heads=2,
                     d_ff=64, max_len=24)


@pytest.fixture(scope="module")
def analyzer():
    return TaskAnalyzer(CFG, seed=3)


def _tokens(analyzer, b, seed=0):
    texts = _texts(b, seed)
    return analyzer.encode_batch(texts), texts


def _texts(b, seed=0):
    rng = np.random.default_rng(seed)
    vocab = ["summarize", "translate", "code", "legal", "brief",
             "question", "urgent", "report", "python", "medical"]
    return [" ".join(rng.choice(vocab, size=int(rng.integers(2, 12))))
            for _ in range(b)]


# ----------------------------------------------------------------------
# kernel vs oracle parity (repro.kernels.ref)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_analyze_step_matches_ref(analyzer, quant):
    """``ops.analyze_step`` == ``ref.analyze_step`` — the oracle is an
    independently-written jnp encoder, so this pins the moved
    ``analyzer_forward`` AND the on-device argmax/confidence epilogue."""
    params = quantize_int8(analyzer.params) if quant else analyzer.params
    toks, _ = _tokens(analyzer, 5, seed=1)
    got = K.analyze_step(params, CFG, toks)
    want = R.analyze_step(params, CFG, toks, pad_id=PAD_ID)
    np.testing.assert_array_equal(got["tt_idx"],
                                  np.asarray(want["tt_idx"]))
    np.testing.assert_array_equal(got["dm_idx"],
                                  np.asarray(want["dm_idx"]))
    np.testing.assert_allclose(got["cx"], np.asarray(want["cx"]),
                               atol=2e-5)
    np.testing.assert_allclose(got["conf"], np.asarray(want["conf"]),
                               atol=2e-5)


@pytest.mark.parametrize("with_fb,with_ad,with_load", [
    (False, False, False), (True, False, False), (True, True, True)])
def test_analyze_route_step_matches_ref(analyzer, with_fb, with_ad,
                                        with_load):
    """The full fused program == ``ref.analyze_route_step`` (oracle
    encoder composed with the unpadded oracle ``route_step``)."""
    rng = np.random.default_rng(11)
    n, m = 20, 8
    nt, nd = len(TASK_TYPES), len(DOMAINS)
    emb = rng.random((n, m)).astype(np.float32)
    tt = np.vstack([rng.random((nt, n)) < 0.4, np.ones((1, n), bool)])
    dm = np.vstack([rng.random((nd, n)) < 0.5, np.ones((1, n), bool)])
    gmask = rng.random(n) < 0.3
    toks, _ = _tokens(analyzer, 6, seed=2)
    W = rng.random((6, m)).astype(np.float32)
    kw = {}
    if with_fb:
        kw["fb_table"] = rng.normal(
            size=(nt * nd * 4, n)).astype(np.float32) * 0.1
        kw["fb_weight"] = 0.7
    if with_ad:
        dc = m + 1                       # bandit context + intercept
        kw["theta"] = rng.normal(size=(n, dc)).astype(np.float32) * 0.1
        kw["ainv"] = np.stack([np.eye(dc, dtype=np.float32)] * n)
        kw["alpha"] = 0.4
        kw["ad_weight"] = 0.5
    if with_load:
        kw["lpen"] = rng.random(n).astype(np.float32)
    got = K.analyze_route_step(
        analyzer.params, CFG, toks, emb, tt, dm, gmask, W,
        k=5, r=5, threshold=0.08, acc_col=0, **kw)
    want = R.analyze_route_step(
        analyzer.params, CFG, toks, emb, tt, dm, gmask, W, 5, 5,
        threshold=0.08, acc_col=0, pad_id=PAD_ID, **kw)
    for key in ("tt_idx", "dm_idx", "model_idx", "stage",
                "n_filtered", "n_candidates"):
        np.testing.assert_array_equal(got[key], np.asarray(want[key]),
                                      err_msg=key)
    for key in ("cx", "conf", "score", "similarity", "task_vectors"):
        np.testing.assert_allclose(got[key], np.asarray(want[key]),
                                   atol=2e-4, err_msg=key)


# ----------------------------------------------------------------------
# fused vs staged differential (the semantic pin)
# ----------------------------------------------------------------------

def _engine(mres, **kw):
    return RoutingEngine(mres, kw.pop("feedback", None), knn_k=6, **kw)


def _staged_decisions(eng, analyzer, texts, prefs):
    sigs = analyzer.analyze_batch(texts)
    return sigs, eng.route_many(prefs, sigs)


@pytest.mark.parametrize("b", [1, 3, 8, 17])
def test_fused_tokens_path_matches_staged(analyzer, b):
    """tokens->decision in ONE program == analyze_batch -> route_many,
    decision-identical (model, stage, signature) at every batch size
    including the B=1 interactive shape."""
    mres = random_catalog(24, seed=5)
    eng = _engine(mres)
    texts = _texts(b, seed=b)
    prefs = "balanced"
    toks = analyzer.encode_batch(texts)
    batch = eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                                   prefs)
    sigs, staged = _staged_decisions(eng, analyzer, texts, prefs)
    assert batch.models() == [d.model for d in staged]
    for i, (sig, d) in enumerate(zip(sigs, staged)):
        got = batch.signature(i)
        assert (got.task_type, got.domain) == (sig.task_type, sig.domain)
        assert got.complexity == pytest.approx(sig.complexity, abs=1e-5)
        assert got.confidence == pytest.approx(sig.confidence, abs=1e-5)
        assert batch.fallback_kind(i) == d.fallback_kind
        assert batch.score[i] == pytest.approx(d.score, abs=1e-4)


@pytest.mark.parametrize("threshold", [0.0, 0.12, 1.1])
def test_fused_confidence_threshold_matches_staged(analyzer, threshold):
    """The in-program confidence gate (traced scalar) replicates the
    host-side thresholding at always-confident, mixed, and
    never-confident settings — exercising both ANY-row fallbacks."""
    mres = random_catalog(16, seed=6)
    eng = _engine(mres, confidence_threshold=threshold)
    texts = _texts(9, seed=31)
    toks = analyzer.encode_batch(texts)
    batch = eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                                   "cost-effective")
    _, staged = _staged_decisions(eng, analyzer, texts, "cost-effective")
    assert batch.models() == [d.model for d in staged]
    assert [batch.fallback_kind(i) for i in range(len(batch))] == \
        [d.fallback_kind for d in staged]


def test_fused_feedback_bias_table_matches_staged(analyzer):
    """The dense per-cluster bias table gathered in-program == the
    staged ``bias_batch`` keyed on materialized signatures."""
    mres = random_catalog(12, seed=7)
    fs = FeedbackStore()
    texts = _texts(10, seed=17)
    sigs = analyzer.analyze_batch(texts)
    names = mres.snapshot()[1]
    rng = np.random.default_rng(3)
    for s in sigs[::2]:
        fs.record(s, names[int(rng.integers(len(names)))],
                  bool(rng.integers(2)))
    eng = _engine(mres, feedback=fs, feedback_weight=2.5)
    toks = analyzer.encode_batch(texts)
    batch = eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                                   "balanced")
    _, staged = _staged_decisions(eng, analyzer, texts, "balanced")
    assert batch.models() == [d.model for d in staged]
    np.testing.assert_allclose(batch.score,
                               [d.score for d in staged], atol=1e-4)


def test_fused_int8_analyzer_within_quant_error(analyzer):
    """int8 analyzer through the fused program: decisions match the
    int8 STAGED path exactly (same numerics end to end), and the
    int8-vs-fp32 signature drift stays within the quantization error
    budget."""
    q = TaskAnalyzer(CFG, seed=3)
    q.params = quantize_int8(q.params)
    mres = random_catalog(16, seed=8)
    eng = _engine(mres)
    texts = _texts(12, seed=23)
    toks = q.encode_batch(texts)
    batch = eng.route_tokens_batch(q.params, q.cfg, toks, "balanced")
    sigs_q, staged = _staged_decisions(eng, q, texts, "balanced")
    assert batch.models() == [d.model for d in staged]
    sigs_f = analyzer.analyze_batch(texts)       # fp32 reference
    drift = [abs(a.complexity - b.complexity)
             for a, b in zip(sigs_q, sigs_f)]
    assert max(drift) < 0.15, f"int8 complexity drift {max(drift)}"


def test_bandit_and_load_blend_in_fused_program(analyzer):
    """LinUCB posterior + load penalty ride the fused dispatch and
    match the staged blend."""
    from repro.adaptive.bandit import LinearBandit
    from repro.core.preferences import N_METRICS
    from repro.serving.load import LoadTracker
    mres = random_catalog(12, seed=9)
    names = mres.snapshot()[1]
    bandit = LinearBandit(len(names), policy="linucb", alpha=0.3)
    rng = np.random.default_rng(5)
    bandit.update(rng.random((6, N_METRICS)).astype(np.float32),
                  rng.integers(0, len(names), 6),
                  rng.random(6).astype(np.float32))
    load = LoadTracker(len(names), capacity=2.0)
    for j in range(4):
        load.admit(j % len(names))
    eng = _engine(mres, adaptive=bandit, adaptive_weight=0.6,
                  load=load, load_weight=0.4)
    texts = _texts(7, seed=41)
    toks = analyzer.encode_batch(texts)
    batch = eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                                   "balanced")
    _, staged = _staged_decisions(eng, analyzer, texts, "balanced")
    assert batch.models() == [d.model for d in staged]
    np.testing.assert_allclose(batch.score,
                               [d.score for d in staged], atol=1e-4)


# ----------------------------------------------------------------------
# vectorized tokenizer / pruning == per-row reference
# (randomized hypothesis variants live in tests/test_properties.py)
# ----------------------------------------------------------------------

EDGE_TEXTS = ["", "   ", "a", "hello world", "HELLO World",
              "don't STOP!!! 42 times...", "x " * 200,
              "tabs\tand\nnewlines here", "!!!...???", "é café"]


@pytest.mark.parametrize("max_len", [1, 2, 7, 24])
def test_encode_batch_matches_encode_reference(max_len):
    """Vectorized ``encode_batch`` is bit-identical to the per-row
    reference ``encode`` loop over the edge-case corpus (empty text,
    whitespace-only, truncation, punctuation, unicode)."""
    tok = HashTokenizer(128)
    got = tok.encode_batch(EDGE_TEXTS, max_len)
    want = np.full((len(EDGE_TEXTS), max_len), PAD_ID, np.int32)
    for i, t in enumerate(EDGE_TEXTS):
        ids = tok.encode(t, max_len)
        want[i, :len(ids)] = ids
    np.testing.assert_array_equal(got, want)
    assert tok.encode_batch([], max_len).shape == (0, max_len)


def test_prune_texts_matches_prune_text_reference():
    """Batch pruning == per-text reference pruning, across the budget
    boundary (word counts straddle prune_head+prune_tail+prune_mid):
    same rng stream per text, so the kept-index sets are identical."""
    cfg = AnalyzerConfig(prune_head=10, prune_tail=6, prune_mid=4)
    lengths = [0, 1, 19, 20, 21, 50, 300]
    texts = [" ".join(f"w{i}" for i in range(n)) for n in lengths]
    for seed in (0, 7):
        assert prune_texts(cfg, texts, seed=seed) == \
            [prune_text(cfg, t, seed=seed) for t in texts]
    assert prune_texts(cfg, [], seed=0) == []


# ----------------------------------------------------------------------
# regressions: empty batch, B=1 bucket reuse, dispatch accounting
# ----------------------------------------------------------------------

def test_analyze_batch_empty_is_fast_path(analyzer):
    """Regression: analyze_batch([]) used to pad to a bucket of 1 and
    run the forward on a garbage row; now it returns [] without any
    device dispatch."""
    before = K.route_step_stats()
    assert analyzer.analyze_batch([]) == []
    after = K.route_step_stats()
    assert after["analyze_step_dispatches"] == \
        before["analyze_step_dispatches"]


def test_ops_entries_reject_empty_batch(analyzer):
    """B=0 is the CALLERS' fast path — the bucketed dispatchers fail
    loud rather than pad an empty batch onto the device."""
    empty = np.zeros((0, CFG.max_len), np.int32)
    with pytest.raises(AssertionError):
        K.analyze_step(analyzer.params, CFG, empty)


def test_interactive_route_reuses_batch_bucket(analyzer):
    """Regression: interactive ``OptiRoute.route`` compiled its own
    B=1 analyzer shape.  Routing through the shared bucketed entry, a
    single query after a batch adds dispatches but ZERO compiles (both
    ride the 8-row-floor bucket)."""
    router = OptiRoute(random_catalog(16, seed=12), analyzer)
    router.route_all(_texts(5, seed=51), "balanced")   # warm bucket 8
    warm = K.route_step_stats()
    rq = router.route("one interactive question", "balanced")
    assert rq.model in set(router.mres.snapshot()[1])
    stats = K.route_step_stats()
    assert stats["analyze_step_compiles"] == warm["analyze_step_compiles"]
    assert stats["route_step_compiles"] == warm["route_step_compiles"]
    assert stats["route_step_dispatches"] == \
        warm["route_step_dispatches"] + 1


def test_fused_one_dispatch_zero_recompiles_after_warmup(analyzer):
    """The tokens->decision path: exactly ONE device dispatch per
    routed batch and zero recompiles across mixed batch sizes after
    the buckets are warm."""
    mres = random_catalog(20, seed=13)
    eng = _engine(mres)
    for b in (1, 9):                       # warm buckets 8 and 16
        toks = analyzer.encode_batch(_texts(b, seed=b))
        eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                               "balanced")
    warm = K.route_step_stats()
    replay = (3, 1, 12, 8, 5, 16, 2)
    for i, b in enumerate(replay):
        toks = analyzer.encode_batch(_texts(b, seed=100 + i))
        eng.route_tokens_batch(analyzer.params, analyzer.cfg, toks,
                               "balanced")
    stats = K.route_step_stats()
    assert stats["route_step_compiles"] == warm["route_step_compiles"]
    assert stats["analyze_step_compiles"] == \
        warm["analyze_step_compiles"]
    # the fused dispatch feeds BOTH counter families, one per batch
    assert stats["route_step_dispatches"] == \
        warm["route_step_dispatches"] + len(replay)
    assert stats["analyze_step_dispatches"] == \
        warm["analyze_step_dispatches"] + len(replay)


def test_fused_emits_one_hook_event_per_batch(analyzer):
    """The recompile hook sees exactly one path="fused" event per
    routed batch, with the analyzer quantization folded into the
    bucket signature."""
    mres = random_catalog(12, seed=14)
    eng = _engine(mres)
    events = []
    old = K.set_recompile_hook(events.append)
    try:
        for b in (4, 7, 2):
            toks = analyzer.encode_batch(_texts(b, seed=b + 60))
            eng.route_tokens_batch(analyzer.params, analyzer.cfg,
                                   toks, "balanced")
    finally:
        K.set_recompile_hook(old)
    fused = [e for e in events if e["path"] == "fused"]
    assert len(fused) == 3
    assert all(e["quant"] == (False, False) for e in fused)
    assert [e["q_bucket"] for e in fused] == [8, 8, 8]


def test_route_tokens_batch_empty_and_guards(analyzer):
    """B=0 short-circuits (empty RoutingBatch with analyzer arrays);
    non-fusable configs fail loud."""
    mres = random_catalog(8, seed=15)
    eng = _engine(mres)
    empty = np.zeros((0, CFG.max_len), np.int32)
    batch = eng.route_tokens_batch(analyzer.params, analyzer.cfg,
                                   empty, "balanced")
    assert len(batch) == 0 and batch.signatures() == []
    eng_off = _engine(mres, fused=False)
    with pytest.raises(ValueError):
        eng_off.route_tokens_batch(analyzer.params, analyzer.cfg,
                                   empty, "balanced")


def test_signature_accessor_requires_fused_batch():
    """Batches from the sig-first path carry no analyzer outputs —
    ``signature`` must say so instead of returning garbage."""
    from tests.test_routing_batch import random_queries
    eng = RoutingEngine(random_catalog(8, seed=16), knn_k=4)
    prefs, sigs = random_queries(3, seed=16)
    batch = eng.route_many_batch(prefs, sigs)
    with pytest.raises(ValueError):
        batch.signature(0)


def test_stub_analyzer_keeps_staged_path():
    """Analyzers without ``supports_fused_route`` (stubs, oracles)
    keep the staged analyze->route pipeline."""
    router = OptiRoute(random_catalog(8, seed=17), StubAnalyzer())
    assert not router._fully_fused_ok()
    out = router.route_all(["q1", "q2"], "balanced")
    assert len(out) == 2 and out[0].sig.task_type == "chat"


# ----------------------------------------------------------------------
# observability wiring
# ----------------------------------------------------------------------

def test_fused_telemetry_and_export_wiring(analyzer):
    """One fused dispatch lands in BOTH Telemetry counter families,
    flows to the prometheus exposition, and round-trips through
    ``metrics_from_prom`` (the SLO gate's view)."""
    from repro.core.telemetry import Telemetry
    from repro.obs import Tracer
    from repro.obs.export import metrics_from_prom, prometheus_text
    tel, tr = Telemetry(), Tracer()
    router = OptiRoute(random_catalog(10, seed=18), analyzer,
                       telemetry=tel, tracer=tr)
    router.route_all(_texts(4, seed=71), "balanced")
    rs, an = tel.route_step_stats(), tel.analyze_step_stats()
    assert rs["dispatches"] == 1 and an["dispatches"] == 1
    assert rs["compiles"] == an["compiles"]
    m = metrics_from_prom(prometheus_text(tel, tracer=tr))
    assert m["analyze_step_dispatches"] == 1.0
    assert m["analyze_step_compiles"] == float(an["compiles"])
    (span,) = [s for s in tr.spans() if s.name == "route_step"]
    assert span.attrs["path"] == "fused"
    assert span.attrs["q_bucket"] == 8
    assert span.attrs["analyzer_quant"] is False
    assert "compiles" in span.attrs
    (asp,) = [s for s in tr.spans() if s.name == "analyze"]
    assert asp.attrs == {"path": "fused", "batch": 4}


def test_staged_analyze_batch_reports_analyze_step(analyzer):
    """The solo bucketed analyzer dispatch (staged path) feeds the
    analyze_step counters and its own tracer span."""
    from repro.core.telemetry import Telemetry
    from repro.obs import Tracer
    tel, tr = Telemetry(), Tracer()
    old_tel, old_tr = analyzer.telemetry, analyzer.tracer
    analyzer.telemetry, analyzer.tracer = tel, tr
    try:
        analyzer.analyze_batch(_texts(3, seed=81))
    finally:
        analyzer.telemetry, analyzer.tracer = old_tel, old_tr
    stats = tel.analyze_step_stats()
    assert stats["dispatches"] == 1
    assert tel.route_step_stats()["dispatches"] == 0
    (span,) = [s for s in tr.spans() if s.name == "analyze_step"]
    assert span.attrs["path"] == "analyze"
    assert span.attrs["n_bucket"] == CFG.max_len


def test_feedback_bias_table_identity_and_version():
    """``bias_table`` memoizes on the store version: identical until
    feedback changes (so the device-side padded copy caches on id),
    rebuilt - and re-keyed - after a record."""
    from repro.core.preferences import TaskSignature
    fs = FeedbackStore()
    names = ["m0", "m1", "m2"]
    t0 = fs.bias_table(names)
    assert t0.shape == (len(TASK_TYPES) * len(DOMAINS) * 4, 3)
    assert t0 is fs.bias_table(names)
    v0 = fs.version()
    sig = TaskSignature(task_type=TASK_TYPES[1], domain=DOMAINS[2],
                        complexity=0.9, confidence=0.8)
    fs.record(sig, "m1", True)
    assert fs.version() == v0 + 1
    t1 = fs.bias_table(names)
    assert t1 is not t0
    row = (1 * len(DOMAINS) + 2) * 4 + min(int(0.9 * 4), 3)
    assert t1[row, 1] == pytest.approx(fs.bias(sig, names)[1])
