"""End-to-end trace propagation through the serving path: every
Response (hit / miss / rerouted / shed) carries a trace id whose span
tree contains exactly the stages that ran for it."""
import pytest

from repro.cache.semantic import SemanticCache
from repro.core.orchestrator import OptiRoute
from repro.core.telemetry import Telemetry
from repro.obs import Tracer
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import LoadTracker
from tests.test_routing_batch import StubAnalyzer, random_catalog


def build_engine(*, load=None, cache=None, tracer=True, seed=4):
    tel = Telemetry()
    tr = Tracer() if tracer else None
    router = OptiRoute(random_catalog(8, seed=seed), StubAnalyzer(),
                       telemetry=tel, tracer=tr, load=load, cache=cache)
    return ServingEngine(router), tel, tr


def _req(i, text=None, deadline_ms=None):
    return Request(text=text or f"query {i}", prefs="balanced", id=i,
                   max_new=4, deadline_ms=deadline_ms,
                   tenant=f"team{i % 2}")


def _child_names(tree):
    return sorted(c["name"] for c in tree["children"])


def _expected_stages(resp, req, *, cache_attached, load_attached):
    """The stages that actually ran for this response."""
    stages = []
    if cache_attached:
        stages.append("cache_lookup")
    if not resp.cache_hit:
        stages += ["analyze", "route_step"]
        if load_attached and req.deadline_ms is not None:
            stages.append("admission")
        if not resp.shed:
            stages.append("generate")
    return sorted(stages)


def test_every_response_trace_matches_stages_ran():
    """Mixed batch — no-SLO misses, SLO-carrying admits, and forced
    sheds — each response's span tree holds exactly its own stages."""
    eng, _, tr = build_engine(load=LoadTracker(8),
                              cache=SemanticCache(capacity=64))
    reqs = []
    for i in range(9):
        # i%3==0: no SLO; ==1: generous SLO (admitted); ==2: impossible
        # SLO (every arm's estimate exceeds 1us -> shed)
        dl = (None, 10_000.0, 1e-3)[i % 3]
        reqs.append(_req(i, deadline_ms=dl))
    out = eng.submit(reqs)
    assert [r.admission for r in out[2::3]] == ["shed"] * 3
    assert all(not r.cache_hit for r in out)     # cold cache
    for req, resp in zip(reqs, out):
        assert resp.trace_id, "untraced response"
        tree = tr.summary_tree(resp.trace_id)
        assert tree["name"] == "request"
        assert tree["attrs"]["request_id"] == req.id
        assert tree["attrs"]["tenant"] == req.tenant
        assert tree["attrs"]["admission"] == resp.admission
        assert tree["attrs"]["model"] == resp.model
        assert tree["attrs"]["cache_hit"] is False
        assert _child_names(tree) == _expected_stages(
            resp, req, cache_attached=True, load_attached=True)
    # the shed trees stop at admission: verdict recorded, no generate
    shed_tree = tr.summary_tree(out[2].trace_id)
    (adm,) = [c for c in shed_tree["children"]
              if c["name"] == "admission"]
    assert adm["attrs"]["verdict"] == "shed"
    assert adm["attrs"]["est_latency_s"] > 0


def test_cache_hit_trace_short_circuits():
    """A cache hit's tree contains ONLY the lookup — no analyze /
    route_step / admission / generate span exists for it."""
    eng, _, tr = build_engine(load=LoadTracker(8),
                              cache=SemanticCache(capacity=64))
    reqs = [_req(i) for i in range(4)]
    first = eng.submit(reqs)
    eng.observe(first, [0.9] * len(first))       # validate -> store
    second = eng.submit([_req(i) for i in range(4)])
    assert all(r.cache_hit for r in second)
    for r in second:
        tree = tr.summary_tree(r.trace_id)
        assert tree["attrs"]["cache_hit"] is True
        assert _child_names(tree) == ["cache_lookup"]
        (lookup,) = tree["children"]
        assert lookup["attrs"]["outcome"] == "hit"
    # the misses' trees keep their full pipeline, with miss outcomes
    for r in first:
        tree = tr.summary_tree(r.trace_id)
        lookups = [c for c in tree["children"]
                   if c["name"] == "cache_lookup"]
        assert lookups[0]["attrs"]["outcome"] == "miss"
        assert "generate" in _child_names(tree)


def test_rerouted_response_trace():
    """Saturating the routed model makes admission fall to a candidate
    that fits; the trace records the rerouted verdict and still shows a
    generate span (the request WAS served)."""
    load = LoadTracker(8)
    # seed=2's catalog keeps 3 candidates after filtering, so admission
    # has lower-ranked alternates to fall to
    eng, _, tr = build_engine(load=load, seed=2)
    probe = eng.submit([_req(0)])[0]             # learn the routed model
    names = list(eng.router.mres.snapshot()[1])
    load.admit(names.index(probe.model), count=100)   # swamp it
    (resp,) = eng.submit([_req(1, text="fresh text",
                               deadline_ms=500.0)])
    assert resp.admission == "rerouted"
    assert resp.model != probe.model
    tree = tr.summary_tree(resp.trace_id)
    assert tree["attrs"]["admission"] == "rerouted"
    (adm,) = [c for c in tree["children"] if c["name"] == "admission"]
    assert adm["attrs"]["verdict"] == "rerouted"
    assert "generate" in _child_names(tree)


def test_batch_trace_tree_spans_whole_pipeline():
    """The batch-level 'submit' root nests the fused stage spans —
    including the route_step span recorded down in kernels/ops with
    its bucket attributes — via contextvar propagation alone."""
    eng, _, tr = build_engine(load=LoadTracker(8),
                              cache=SemanticCache(capacity=64))
    out = eng.submit([_req(i, deadline_ms=10_000.0) for i in range(5)])
    (submit,) = [s for s in tr.spans() if s.name == "submit"]
    tree = tr.summary_tree(submit.trace_id)
    assert tree["name"] == "submit"
    assert tree["attrs"] == {"batch": 5, "mode": "interactive"}
    names = _child_names(tree)
    for stage in ("cache_lookup", "analyze", "route_step",
                  "admission", "generate"):
        assert stage in names, f"missing {stage} in {names}"
    (rs,) = [c for c in tree["children"] if c["name"] == "route_step"]
    assert rs["attrs"]["batch"] == 5
    assert rs["attrs"]["q_bucket"] >= 5
    assert rs["attrs"]["path"] in ("dense", "sharded", "ivf")
    assert "compiles" in rs["attrs"]
    # per-request roots are separate traces linking back to the batch
    for r in out:
        tree_r = tr.summary_tree(r.trace_id)
        assert r.trace_id != submit.trace_id
        assert tree_r["attrs"]["batch_trace"] == submit.trace_id


def test_observe_attaches_outcome_span():
    eng, _, tr = build_engine(cache=SemanticCache(capacity=64))
    out = eng.submit([_req(i) for i in range(3)])
    eng.observe(out, [0.8, 0.6, 0.7])
    for r, q in zip(out, (0.8, 0.6, 0.7)):
        tree = tr.summary_tree(r.trace_id)
        (obs,) = [c for c in tree["children"] if c["name"] == "observe"]
        assert obs["attrs"]["quality"] == pytest.approx(q)
        assert obs["attrs"]["model"] == r.model


def test_untraced_engine_unchanged():
    eng, _, tr = build_engine(tracer=False)
    out = eng.submit([_req(0)])
    assert tr is None
    assert out[0].trace_id == "" and out[0].trace_root is None
