import os

# Multi-device host platform BEFORE anything imports jax (pattern from
# launch/dryrun.py): the sharded mega-catalog route-step tests need
# >= 4 CPU devices.  Respect an explicit caller override.
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.core.mres import MRES, ModelEntry

# ---------------------------------------------------------------------
# opt-in runtime sanitizers (REPRO_SANITIZE=1) — see repro.analysis
# ---------------------------------------------------------------------
_SANITIZE = sanitize.enabled()

if _SANITIZE:
    import jax

    # transfer_guard: default "allow" — the CPU/interpreter fallback
    # paths legitimately shuttle host<->device; tighten per-run with
    # REPRO_TRANSFER_GUARD=disallow/log to hunt stray transfers.
    jax.config.update("jax_transfer_guard",
                      os.environ.get("REPRO_TRANSFER_GUARD", "allow"))

    _SENTINEL = sanitize.RecompileSentinel().install()

    @pytest.fixture(autouse=True)
    def _sanitizers(request):
        """Per-test: fail on tracer leaks, steady-state route-step
        recompiles, and lock-order inversions observed during the test."""
        # per-test warmup window: tests may legitimately clear jit
        # caches (perf/compile-count tests), so cross-test recompiles
        # are not violations — re-compiling a bucket the SAME test
        # already dispatched is
        _SENTINEL.forget()
        n_lock_viol = len(sanitize.lock_order_violations())
        with jax.checking_leaks():
            yield
        recompiles = _SENTINEL.drain()
        if recompiles:
            pytest.fail("recompile sentinel tripped:\n  "
                        + "\n  ".join(recompiles), pytrace=False)
        lock_viol = sanitize.lock_order_violations()[n_lock_viol:]
        if lock_viol:
            msgs = [f"{a} -> {b} closes cycle {' -> '.join(cyc)}"
                    for a, b, cyc in lock_viol]
            pytest.fail("lock-order inversion(s) detected:\n  "
                        + "\n  ".join(msgs), pytrace=False)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_entry(name, *, accuracy=0.5, latency_ms=100.0, cost=1.0,
               task_types=("chat",), domains=("general",),
               generalist=False, family="dense", n_params=0, **ethics):
    raw = {
        "accuracy": accuracy, "latency_ms": latency_ms,
        "cost_per_mtok": cost,
        "helpfulness": ethics.get("helpfulness", 0.5),
        "harmlessness": ethics.get("harmlessness", 0.5),
        "honesty": ethics.get("honesty", 0.5),
        "steerability": ethics.get("steerability", 0.5),
        "creativity": ethics.get("creativity", 0.5),
    }
    return ModelEntry(name=name, raw_metrics=raw, task_types=task_types,
                      domains=domains, generalist=generalist,
                      family=family, n_params=n_params)


@pytest.fixture
def small_mres():
    """4-model catalog spanning the cost/accuracy trade-off."""
    m = MRES()
    m.register(make_entry("tiny-fast", accuracy=0.4, latency_ms=5, cost=0.1,
                          task_types=("chat", "classification"),
                          domains=("general",), generalist=True))
    m.register(make_entry("mid", accuracy=0.7, latency_ms=40, cost=1.0,
                          task_types=("chat", "code", "summarization"),
                          domains=("general", "software")))
    m.register(make_entry("big-accurate", accuracy=0.95, latency_ms=400,
                          cost=10.0, helpfulness=0.9, honesty=0.9,
                          task_types=("chat", "code", "reasoning",
                                      "summarization"),
                          domains=("general", "software", "finance",
                                   "legal"), generalist=True))
    m.register(make_entry("legal-specialist", accuracy=0.85, latency_ms=120,
                          cost=3.0, harmlessness=0.95,
                          task_types=("summarization", "classification"),
                          domains=("legal",)))
    return m
