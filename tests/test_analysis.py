"""Lint-engine tests: good/bad fixture pairs per rule, suppression
syntax, the baseline ratchet, JSON schema stability, and a self-check
that the repo itself lints clean against the checked-in baseline."""
import json
import os
import textwrap
from pathlib import Path

import pytest

from repro.analysis.engine import lint_source, run_lint
from repro.analysis.findings import (RULES, SCHEMA_VERSION, Finding,
                                     load_baseline, save_baseline,
                                     split_new, stale_baseline)
from repro.analysis.lint import main as lint_main

REPO_ROOT = Path(__file__).resolve().parent.parent


def snip(text: str, rel: str = "serving/snippet.py"):
    return lint_source(textwrap.dedent(text), rel=rel)


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------

LOCKED_CLASS = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []
            self.total = 0

        def add(self, x):
            with self._lock:
                self.items.append(x)
                self.total += 1
"""


def test_lock_mixed_mutation_bad():
    findings = snip(LOCKED_CLASS + """
        def sneaky(self, x):
            self.items.append(x)
    """)
    assert rules_of(findings) == ["lock-mixed-mutation"]
    assert findings[0].symbol == "C.sneaky"


def test_lock_mixed_mutation_good_all_locked():
    assert snip(LOCKED_CLASS) == []


def test_lock_init_is_pre_publication():
    # the __init__ assignments themselves are unlocked mutations of
    # guarded attrs but must not be flagged
    findings = snip(LOCKED_CLASS)
    assert findings == []


def test_lock_locked_suffix_convention():
    # *_locked methods are called with the lock held — no finding
    findings = snip(LOCKED_CLASS + """
        def _flush_locked(self):
            self.items.clear()
            self.total = 0
    """)
    assert findings == []


def test_lock_unlocked_read_bad():
    findings = snip(LOCKED_CLASS + """
        def totals(self):
            return (len(self.items), self.total)
    """)
    assert rules_of(findings) == ["lock-unlocked-read"]
    assert findings[0].symbol == "C.totals"
    assert "items" in findings[0].message and "total" in findings[0].message


def test_lock_unlocked_read_good_under_lock():
    findings = snip(LOCKED_CLASS + """
        def totals(self):
            with self._lock:
                return (len(self.items), self.total)
    """)
    assert findings == []


def test_lock_unlocked_read_single_attr_below_threshold():
    # one guarded attr alone is an atomic snapshot under the GIL
    findings = snip(LOCKED_CLASS + """
        def count(self):
            return len(self.items)
    """)
    assert findings == []


def test_lock_unlocked_read_private_method_exempt():
    findings = snip(LOCKED_CLASS + """
        def _peek(self):
            return (len(self.items), self.total)
    """)
    assert findings == []


def test_lock_module_global_mixed_mutation():
    findings = snip("""
        import threading

        _LOCK = threading.Lock()
        _STATS = {"a": 0}

        def bump():
            with _LOCK:
                _STATS["a"] += 1

        def sneaky():
            _STATS["a"] += 1
    """, rel="kernels/snippet.py")
    assert rules_of(findings) == ["lock-mixed-mutation"]
    assert findings[0].symbol == "sneaky"


def test_lock_make_lock_factory_recognized():
    findings = snip("""
        from repro.analysis.sanitize import make_lock

        class C:
            def __init__(self):
                self._lock = make_lock("c")
                self.n = 0

            def inc(self):
                with self._lock:
                    self.n += 1

            def sneaky(self):
                self.n = 0
    """)
    assert rules_of(findings) == ["lock-mixed-mutation"]


# ---------------------------------------------------------------------
# jit hazards
# ---------------------------------------------------------------------

def test_jit_traced_branch_bad():
    findings = snip("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert rules_of(findings) == ["jit-traced-branch"]
    assert findings[0].symbol == "f"


def test_jit_traced_branch_good_static_and_shape():
    findings = snip("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("flag",))
        def g(x, flag):
            if flag:
                x = x + 1
            if x.shape[0] > 2:
                x = x * 2
            if x is None:
                return x
            return x
    """)
    assert findings == []


def test_jit_traced_branch_propagates_through_assignment():
    findings = snip("""
        import jax

        @jax.jit
        def f(x):
            y = x + 1
            while y > 0:
                y = y - 1
            return y
    """)
    assert rules_of(findings) == ["jit-traced-branch"]


def test_jit_wrapped_assignment_form():
    # name = jax.jit(fn) marks fn as a jitted scope
    findings = snip("""
        import jax

        def f(x):
            if x > 0:
                return x
            return -x

        f_jit = jax.jit(f)
    """)
    assert rules_of(findings) == ["jit-traced-branch"]


def test_jit_host_sync_bad():
    findings = snip("""
        import jax

        @jax.jit
        def f(x):
            s = float(x.sum())
            return s + x.mean().item()
    """)
    assert rules_of(findings) == ["jit-host-sync", "jit-host-sync"]


def test_jit_host_sync_good_outside_jit():
    findings = snip("""
        def report(x):
            return float(x.sum())
    """)
    assert findings == []


def test_jit_kernel_body_kwargs_are_static():
    # Pallas kernel bodies: *_ref params traced, keyword params static
    findings = snip("""
        def _toy_kernel(x_ref, o_ref, *, causal, softcap):
            if causal:
                o_ref[...] = x_ref[...]
            if softcap > 0:
                o_ref[...] = x_ref[...] * softcap
    """, rel="kernels/toy.py")
    assert findings == []


def test_jit_kernel_body_ref_branch_flagged():
    findings = snip("""
        def _toy_kernel(x_ref, o_ref):
            if x_ref[0] > 0:
                o_ref[...] = x_ref[...]
    """, rel="kernels/toy.py")
    assert rules_of(findings) == ["jit-traced-branch"]


def test_jit_constant_rebuild_bad():
    findings = snip("""
        import jax.numpy as jnp

        def f():
            return jnp.asarray([1.0, 2.0, 3.0])
    """)
    assert rules_of(findings) == ["jit-constant-rebuild"]


def test_jit_constant_rebuild_good_module_scope_or_variable():
    findings = snip("""
        import jax.numpy as jnp

        _C = jnp.asarray([1.0, 2.0, 3.0])

        def f(xs):
            return jnp.asarray(xs)
    """)
    assert findings == []


def test_jit_bucket_bypass_bad():
    findings = snip("""
        from repro.kernels.route_step import route_step_jit

        def f(*args):
            return route_step_jit(*args)
    """)
    assert rules_of(findings) == ["jit-bucket-bypass"]


def test_jit_bucket_bypass_good_sanctioned_and_in_kernels():
    assert snip("""
        from repro import kernels as K

        def f(*args):
            return K.route_step(*args)
    """) == []
    # raw entries are fair game inside the kernels package itself
    assert snip("""
        from repro.kernels.route_step import route_step_jit

        def f(*args):
            return route_step_jit(*args)
    """, rel="kernels/ops.py") == []


# ---------------------------------------------------------------------
# kernel-oracle conformance (project rule, synthetic tree)
# ---------------------------------------------------------------------

def _kernel_project(tmp_path, *, oracle_for_bar=True, test_refs_foo=True):
    kdir = tmp_path / "src" / "kernels"
    kdir.mkdir(parents=True)
    (kdir / "foo.py").write_text(
        "def foo_pallas(x):\n    return x\n\n"
        "def bar_pallas(x):\n    return x\n")
    ref = "def foo(x):\n    return x\n"
    if oracle_for_bar:
        ref += "\n\ndef bar(x):\n    return x\n"
    (kdir / "ref.py").write_text(ref)
    tdir = tmp_path / "tests"
    tdir.mkdir()
    body = "from repro.kernels.ref import foo\n" if test_refs_foo \
        else "import os\n"
    if oracle_for_bar:
        body += "from repro.kernels.ref import bar\nassert bar\n"
    (tdir / "test_foo.py").write_text(body)
    return run_lint([str(tmp_path / "src")], root=str(tmp_path),
                    tests_dir=str(tdir))


def test_kernel_oracle_clean(tmp_path):
    result = _kernel_project(tmp_path)
    assert result.findings == []


def test_kernel_missing_oracle_fires(tmp_path):
    result = _kernel_project(tmp_path, oracle_for_bar=False)
    assert rules_of(result.findings) == ["kernel-missing-oracle"]
    assert result.findings[0].symbol == "bar_pallas"


def test_kernel_missing_parity_test_fires(tmp_path):
    result = _kernel_project(tmp_path, test_refs_foo=False)
    assert rules_of(result.findings) == ["kernel-missing-parity-test"]
    assert "foo" in result.findings[0].message


# ---------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------

def test_suppression_silences_named_rule():
    findings = snip(LOCKED_CLASS + """
        def sneaky(self, x):
            self.items.append(x)  # lint: ignore[lock-mixed-mutation] -- fixture
    """)
    assert findings == []


def test_suppression_comment_block_above_statement():
    findings = snip(LOCKED_CLASS + """
        def sneaky(self, x):
            # lint: ignore[lock-mixed-mutation] -- a reason that wraps
            # over two comment lines before the flagged statement
            self.items.append(x)
    """)
    assert findings == []


def test_bare_suppression_is_a_finding():
    findings = snip("""
        def f():
            return 1  # lint: ignore
    """)
    assert rules_of(findings) == ["bad-suppression"]


def test_suppression_unknown_rule_is_a_finding():
    findings = snip("""
        def f():
            return 1  # lint: ignore[no-such-rule] -- whatever
    """)
    assert rules_of(findings) == ["bad-suppression"]
    assert "no-such-rule" in findings[0].message


def test_suppression_in_docstring_is_inert():
    findings = snip('''
        def f():
            """Example: # lint: ignore[lock-mixed-mutation] -- nope."""
            return 1
    ''')
    assert findings == []


def test_suppression_does_not_cover_other_rules():
    findings = snip(LOCKED_CLASS + """
        def sneaky(self, x):
            self.items.append(x)  # lint: ignore[jit-host-sync] -- wrong rule
    """)
    assert rules_of(findings) == ["lock-mixed-mutation"]


# ---------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------

def _f(rule="lock-mixed-mutation", path="a.py", line=3, symbol="C.m",
       message="msg"):
    return Finding(rule=rule, path=path, line=line, col=1,
                   symbol=symbol, message=message)


def test_fingerprint_is_line_free():
    assert _f(line=3).fingerprint == _f(line=99).fingerprint
    assert _f(message="x").fingerprint != _f(message="y").fingerprint
    assert _f().fingerprint.startswith("lock-mixed-mutation:")


def test_baseline_roundtrip_and_split(tmp_path):
    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), [_f(), _f(line=9)])
    counts = load_baseline(str(bl))
    assert counts == {_f().fingerprint: 2}
    # 2 baselined + 1 genuinely new
    new, old = split_new([_f(), _f(line=9), _f(message="other")], counts)
    assert [x.message for x in new] == ["other"]
    assert len(old) == 2


def test_baseline_multiplicity_ratchets():
    counts = {_f().fingerprint: 1}
    # second occurrence of a once-baselined finding counts as NEW
    new, old = split_new([_f(), _f(line=50)], counts)
    assert len(new) == 1 and len(old) == 1


def test_stale_baseline_reported():
    counts = {_f().fingerprint: 2, _f(message="gone").fingerprint: 1}
    stale = stale_baseline([_f()], counts)
    assert stale == {_f().fingerprint: 1, _f(message="gone").fingerprint: 1}


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == {}


# ---------------------------------------------------------------------
# CLI (exit codes, --write-baseline, JSON schema)
# ---------------------------------------------------------------------

BAD_FILE = textwrap.dedent(LOCKED_CLASS + """
        def sneaky(self, x):
            self.items.append(x)
""")


def _cli_project(tmp_path):
    pkg = tmp_path / "src"
    pkg.mkdir()
    (pkg / "mod.py").write_text(BAD_FILE)
    return pkg


def test_cli_ratchet_lifecycle(tmp_path, capsys):
    pkg = _cli_project(tmp_path)
    bl = str(tmp_path / "analysis" / "baseline.json")
    args = [str(pkg), "--root", str(tmp_path), "--baseline", bl]
    assert lint_main(args) == 1                  # new finding fails
    assert lint_main(args + ["--write-baseline"]) == 0
    assert lint_main(args) == 0                  # baselined passes
    # a second violation rides in -> fails again
    (pkg / "mod2.py").write_text(BAD_FILE)
    assert lint_main(args) == 1
    capsys.readouterr()


def test_cli_json_schema_stable(tmp_path, capsys):
    pkg = _cli_project(tmp_path)
    rc = lint_main([str(pkg), "--root", str(tmp_path), "--no-baseline",
                    "--json"])
    assert rc == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["schema_version"] == SCHEMA_VERSION
    assert set(doc) == {"schema_version", "n_files", "findings",
                        "baselined", "stale_baseline", "errors"}
    assert doc["n_files"] == 1 and len(doc["findings"]) == 1
    row = doc["findings"][0]
    assert set(row) == {"rule", "path", "line", "col", "symbol",
                        "message", "fingerprint"}
    assert row["rule"] == "lock-mixed-mutation"
    assert row["path"] == "src/mod.py"
    assert row["fingerprint"].split(":")[0] == row["rule"]


def test_rule_catalog_pinned():
    assert set(RULES) == {
        "lock-mixed-mutation", "lock-unlocked-read", "jit-traced-branch",
        "jit-host-sync", "jit-constant-rebuild", "jit-bucket-bypass",
        "kernel-missing-oracle", "kernel-missing-parity-test",
        "bad-suppression"}


# ---------------------------------------------------------------------
# the repo itself is clean against the checked-in baseline
# ---------------------------------------------------------------------

def test_repo_lints_clean_against_baseline():
    result = run_lint([str(REPO_ROOT / "src" / "repro")],
                      root=str(REPO_ROOT),
                      tests_dir=str(REPO_ROOT / "tests"))
    assert result.errors == []
    baseline = load_baseline(str(REPO_ROOT / "analysis" / "baseline.json"))
    new, _old = split_new(result.findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)
