"""Observability package: tracer span trees, fixed-memory metric
primitives, Prometheus export round-trip, SLO rules (point + burn
rate), and the device cost profiler hook."""
import json
import math
import threading
import urllib.request

import numpy as np
import pytest

from repro.core.telemetry import RouteEvent, Telemetry
from repro.obs import (DeviceCostProfiler, Tracer, evaluate_rules,
                       metrics_from_prom, parse_prom_text, parse_rule,
                       parse_rules, prometheus_text, serve_metrics,
                       trace_capture)
from repro.obs.metrics import Counter, Gauge, LogHistogram
from repro.obs.slo import SLOEvaluator
from repro.obs.trace import NOOP_SPAN


def _ev(ts=1.0, model="m0", fallback="", route_s=0.01, cost=2.0):
    return RouteEvent(ts=ts, model=model, task_type="chat",
                      domain="general", complexity=0.5,
                      fallback=fallback, route_s=route_s, sim_cost=cost)


# ----------------------------------------------------------------------
# tracer
# ----------------------------------------------------------------------
def test_span_nesting_via_contextvar():
    """Nesting needs no explicit parent argument: a span opened inside
    another's ``with`` block (even across function boundaries) becomes
    its child in the same trace."""
    tr = Tracer()

    def inner_layer():                 # no span threading through args
        with tr.span("route_step", batch=4):
            pass

    with tr.start_trace("submit", mode="interactive") as root:
        with tr.span("analyze") as mid:
            inner_layer()

    spans = tr.spans(root.trace_id)
    assert [s.name for s in spans] == ["route_step", "analyze", "submit"]
    by_name = {s.name: s for s in spans}
    assert by_name["analyze"].parent_id == root.span_id
    assert by_name["route_step"].parent_id == mid.span_id
    assert all(s.trace_id == root.trace_id for s in spans)
    tree = tr.summary_tree(root.trace_id)
    assert tree["name"] == "submit"
    assert tree["children"][0]["name"] == "analyze"
    assert tree["children"][0]["children"][0]["name"] == "route_step"


def test_start_trace_always_roots():
    tr = Tracer()
    with tr.start_trace("outer"):
        with tr.start_trace("fresh") as f:
            assert f.parent_id == ""
    assert len({s.trace_id for s in tr.spans()}) == 2


def test_span_attrs_and_set():
    tr = Tracer()
    with tr.span("route_step", path="dense") as sp:
        sp.set(compiles=1)
    (s,) = tr.spans()
    assert s.attrs == {"path": "dense", "compiles": 1}
    assert s.duration_s >= 0.0


def test_record_span_fanout():
    """Retrospective fan-out: one already-finished child per request,
    rooted on demand, stamped with the amortized duration."""
    tr = Tracer()
    root = tr.record_span("request", request_id=7, duration_s=0.25)
    child = tr.record_span("generate", parent=root, duration_s=0.2,
                           model="m1")
    assert root.trace_id and root.parent_id == ""
    assert child.trace_id == root.trace_id
    assert child.parent_id == root.span_id
    tree = tr.summary_tree(root.trace_id)
    assert tree["attrs"]["request_id"] == 7
    assert tree["duration_s"] == pytest.approx(0.25)
    assert [c["name"] for c in tree["children"]] == ["generate"]


def test_disabled_tracer_is_noop(tmp_path):
    tr = Tracer(enabled=False)
    with tr.start_trace("submit") as root:
        with tr.span("analyze") as sp:
            sp.set(batch=3)
    assert root is NOOP_SPAN and sp is NOOP_SPAN
    assert tr.record_span("request") is NOOP_SPAN
    assert tr.stats() == {"spans_total": 0, "spans_retained": 0,
                          "max_spans": 16384}
    assert tr.export_jsonl(tmp_path / "t.jsonl") == 0


def test_span_ring_bounded_and_monotonic():
    tr = Tracer(max_spans=8)
    first = tr.record_span("request", i=0)
    for i in range(1, 100):
        tr.record_span("request", i=i)
    stats = tr.stats()
    assert stats == {"spans_total": 100, "spans_retained": 8,
                     "max_spans": 8}
    assert [s.attrs["i"] for s in tr.spans()] == list(range(92, 100))
    assert tr.summary_tree(first.trace_id) is None   # evicted


def test_export_jsonl_roundtrip(tmp_path):
    tr = Tracer()
    with tr.start_trace("submit", batch=2) as root:
        with tr.span("route_step", path="dense"):
            pass
    path = tmp_path / "trace.jsonl"
    assert tr.export_jsonl(path) == 2
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert {r["name"] for r in recs} == {"submit", "route_step"}
    for r in recs:
        assert set(r) == {"trace_id", "span_id", "parent_id", "name",
                          "ts", "duration_s", "attrs"}
        assert r["trace_id"] == root.trace_id
    # filtered export: only the requested trace
    other = Tracer()
    other.record_span("request")
    assert tr.export_jsonl(path, trace_id="t_nonexistent") == 0


def test_current_tracks_ambient_span():
    tr = Tracer()
    assert tr.current() is None
    with tr.span("outer") as o:
        assert tr.current() is o
        with tr.span("inner") as i:
            assert tr.current() is i
        assert tr.current() is o
    assert tr.current() is None


def test_tracer_thread_safe_record():
    tr = Tracer(max_spans=256)

    def worker(k):
        for i in range(200):
            with tr.span(f"w{k}", i=i):
                pass

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = tr.stats()
    assert stats["spans_total"] == 800
    assert stats["spans_retained"] == 256


# ----------------------------------------------------------------------
# metric primitives
# ----------------------------------------------------------------------
def test_counter_gauge_labels():
    c = Counter("reqs", "requests")
    c.inc(), c.inc(2.0, label="m1")
    assert c.value() == 1.0 and c.value("m1") == 2.0
    assert c.items() == {"": 1.0, "m1": 2.0}
    with pytest.raises(AssertionError):
        c.inc(-1.0)
    g = Gauge("depth")
    g.set(3.0, label="m0")
    g.set(1.5, label="m0")
    assert g.value("m0") == 1.5 and g.value("missing") == 0.0


def test_log_histogram_quantile_accuracy():
    h = LogHistogram()
    vals = (np.arange(1, 2001)) / 1000.0        # 1ms .. 2s uniform
    for v in vals:
        h.record(float(v))
    assert h.count == 2000
    assert h.mean() == pytest.approx(float(vals.mean()))
    for q in (0.1, 0.5, 0.9, 0.99):
        assert h.quantile(q) == pytest.approx(float(np.quantile(vals, q)),
                                              rel=0.02)
    qs = h.quantiles((0.5, 0.9))
    assert qs[0] <= qs[1]


def test_log_histogram_single_sample_exact():
    h = LogHistogram()
    h.record(0.5)
    assert h.quantile(0.5) == h.quantile(0.99) == 0.5
    assert h.snapshot() == {"count": 1, "sum": 0.5, "min": 0.5,
                            "max": 0.5}


def test_log_histogram_edges():
    h = LogHistogram(lo=1e-3, hi=1e1)
    assert h.quantile(0.5) == 0.0               # empty
    h.record(0.0)                               # non-positive -> underflow
    h.record(-1.0)
    assert h.count == 2 and h.quantile(0.5) == 0.0
    h.record(1e-9)                              # below lo: clamps to vmin
    h.record(1e9)                               # above hi: clamps to vmax
    assert h.quantile(0.0) >= 0.0
    assert h.quantile(1.0) == 1e9
    assert math.isclose(h.snapshot()["max"], 1e9)


def test_log_histogram_merge():
    a, b = LogHistogram(), LogHistogram()
    for v in (0.01, 0.02, 0.04):
        a.record(v)
    for v in (0.08, 0.16):
        b.record(v)
    ref = LogHistogram()
    for v in (0.01, 0.02, 0.04, 0.08, 0.16):
        ref.record(v)
    a.merge(b)
    assert a.count == 5 and a.total == pytest.approx(ref.total)
    assert a.quantile(0.5) == pytest.approx(ref.quantile(0.5))
    assert a.snapshot() == pytest.approx(ref.snapshot())
    with pytest.raises(AssertionError):         # incompatible buckets
        a.merge(LogHistogram(lo=1e-2, hi=1e2))


# ----------------------------------------------------------------------
# prometheus export
# ----------------------------------------------------------------------
def _filled_telemetry():
    tel = Telemetry()
    for i in range(10):
        tel.record(_ev(ts=100.0 + i, model=f"m{i % 2}",
                       fallback="any" if i == 9 else "",
                       route_s=0.01 * (i + 1)))
    tel.record_admission("admitted", count=8)
    tel.record_admission("shed", count=2)
    tel.record_cache("hit", count=3)
    tel.record_cache("miss", count=7)
    tel.record_route_step(dispatches=5, compiles=1)
    tel.record_sharding(silent_replications=1)
    return tel


def test_prometheus_text_round_trip():
    tel = _filled_telemetry()
    tr = Tracer()
    tr.record_span("request")
    text = prometheus_text(tel, tracer=tr)
    raw = parse_prom_text(text)
    assert raw["repro_events_total"] == 10
    assert raw['repro_requests_total{model="m0"}'] == 5
    assert raw['repro_fallback_total{stage="any"}'] == 1
    assert raw['repro_fallback_total{stage="none"}'] == 9
    assert raw['repro_admission_total{kind="shed"}'] == 2
    assert raw['repro_cache_total{kind="hit"}'] == 3
    assert raw["repro_route_step_dispatches_total"] == 5
    assert raw["repro_route_step_compiles_total"] == 1
    assert raw["repro_sharding_silent_replications_total"] == 1
    assert raw["repro_trace_spans_total"] == 1
    assert raw["repro_route_latency_seconds_count"] == 10
    assert raw['repro_route_latency_seconds{quantile="0.5"}'] > 0
    # derived ratios for the SLO layer
    m = metrics_from_prom(text)
    assert m["shed_rate"] == pytest.approx(0.2)
    assert m["cache_hit_rate"] == pytest.approx(0.3)
    assert m["route_step_compiles"] == 1
    assert m["route_latency_p99"] >= m["route_latency_p50"] > 0


def test_prometheus_export_with_load_and_cost_profile():
    from repro.serving.load import LoadTracker
    tel = _filled_telemetry()
    load = LoadTracker(3)
    load.admit(1, count=4)
    text = prometheus_text(
        tel, load=load,
        cost_profile={"dense/16/128/False/1":
                      {"flops": 1e6, "bytes_accessed": 2e5}})
    raw = parse_prom_text(text)
    assert raw['repro_load_queue_depth{model="1"}'] == 4
    assert raw['repro_load_inflight{model="0"}'] == 0
    key = 'repro_route_step_flops{bucket="dense/16/128/False/1"}'
    assert raw[key] == 1e6


def test_metrics_server_scrape():
    tel = _filled_telemetry()
    with serve_metrics(tel) as srv:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        body = urllib.request.urlopen(url, timeout=5).read().decode()
        assert parse_prom_text(body)["repro_events_total"] == 10
        tel.record(_ev())                       # live: next scrape moves
        body2 = urllib.request.urlopen(url, timeout=5).read().decode()
        assert parse_prom_text(body2)["repro_events_total"] == 11
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/other", timeout=5)


# ----------------------------------------------------------------------
# SLO rules
# ----------------------------------------------------------------------
def test_parse_rule_forms():
    r = parse_rule("route_latency_p99 <= 0.05")
    assert (r.name, r.metric, r.op, r.threshold) == \
        ("route_latency_p99", "route_latency_p99", "<=", 0.05)
    assert not r.is_burn
    r = parse_rule("shed: shed_rate <= 0.01 burn 60s/600s x2")
    assert r.name == "shed" and r.is_burn
    assert (r.burn_short_s, r.burn_long_s, r.burn_factor) == \
        (60.0, 600.0, 2.0)
    r = parse_rule("recompiles: route_step_compiles == 0")
    assert r.check(0.0) and not r.check(1.0)
    for bad in ("nonsense", "x ~ 3", "a <= 0.1 burn 60s",
                "a <= 0.1 burn 600s/60s"):
        with pytest.raises(ValueError):
            parse_rule(bad)


def test_parse_rules_skips_comments():
    rules = parse_rules("# SLOs\n\nshed_rate <= 0.01  # inline\n"
                        "cache_hit_rate >= 0.3\n")
    assert [r.metric for r in rules] == ["shed_rate", "cache_hit_rate"]


def test_point_evaluation_and_missing_metric():
    rules = parse_rules(["shed_rate <= 0.1", "cache_hit_rate >= 0.5",
                         "unknown_metric == 0"])
    v = evaluate_rules(rules, {"shed_rate": 0.2, "cache_hit_rate": 0.7})
    assert [x.ok for x in v] == [False, True, True]   # missing -> 0.0
    assert "BREACH" in v[0].line() and "OK" in v[1].line()


def test_burn_rate_needs_both_windows():
    """A burn-rate rule fires only when the bad fraction exceeds
    factor*threshold over BOTH windows: a brief spike inside a healthy
    long window does not page."""
    rule = parse_rule("shed: shed_rate <= 0.1 burn 60s/600s x2")
    ev = SLOEvaluator([rule])
    # steady healthy traffic for 10 minutes: 1 bad / 100 total per 30s
    t, bad, total = 0.0, 0.0, 0.0
    while t < 600.0:
        bad += 1.0
        total += 100.0
        ev.observe(t, {}, {"shed_rate": (bad, total)})
        t += 30.0
    (v,) = ev.evaluate({"shed_rate": 0.01}, now=600.0)
    assert v.ok
    # short-window spike: 90% bad for one minute; long window still ok
    for _ in range(2):
        bad += 90.0
        total += 100.0
        ev.observe(t, {}, {"shed_rate": (bad, total)})
        t += 30.0
    (v,) = ev.evaluate({"shed_rate": 0.9}, now=t)
    assert v.ok and "burn" in v.detail
    # sustained badness: both windows exceed 2 * 0.1 -> breach
    while t < 1800.0:
        bad += 90.0
        total += 100.0
        ev.observe(t, {}, {"shed_rate": (bad, total)})
        t += 30.0
    (v,) = ev.evaluate({"shed_rate": 0.9}, now=t)
    assert not v.ok


def test_burn_rule_falls_back_to_point_check_without_history():
    rule = parse_rule("shed: shed_rate <= 0.1 burn 60s/600s")
    (v,) = SLOEvaluator([rule]).evaluate({"shed_rate": 0.05})
    assert v.ok and v.detail == "insufficient history"
    (v,) = SLOEvaluator([rule]).evaluate({"shed_rate": 0.5})
    assert not v.ok


def test_slo_cli_gate(tmp_path):
    from repro.obs import slo, write_prom
    prom = tmp_path / "metrics.prom"
    write_prom(prom, _filled_telemetry())
    ok = ["--metrics", str(prom), "--rule", "shed_rate <= 0.5"]
    assert slo.main(ok) == 0
    breach = ["--metrics", str(prom), "--rule",
              "recompiles: route_step_compiles == 0"]
    assert slo.main(breach) == 1                # fixture recorded 1 compile
    assert slo.main(["--metrics", str(prom)]) == 2   # no rules
    rules = tmp_path / "rules.slo"
    rules.write_text("# gate\nshed_rate <= 0.5\nevents >= 1\n")
    assert slo.main(["--metrics", str(prom),
                     "--rules-file", str(rules)]) == 0


# ----------------------------------------------------------------------
# device cost profiler
# ----------------------------------------------------------------------
def test_cost_profiler_captures_route_step_buckets():
    from repro.core.routing import RoutingEngine
    from repro.kernels import ops as K
    from tests.test_routing_batch import random_catalog
    from benchmarks.router_scale import _random_queries
    mres = random_catalog(8, seed=3)
    eng = RoutingEngine(mres, knn_k=4, use_kernel=False)
    prefs, sigs = _random_queries(4, seed=5)
    prof = DeviceCostProfiler()
    K.set_cost_profiler(prof)
    try:
        eng.route_many_batch(prefs, sigs)
        eng.route_many_batch(prefs, sigs)       # same bucket: no recapture
    finally:
        K.set_cost_profiler(None)
    profile = prof.profile()
    assert len(profile) == 1                    # one shape bucket seen
    assert prof.captures + prof.errors == 1     # capture attempted once
    (bucket, costs), = profile.items()
    assert bucket.startswith("dense/")
    assert set(costs) == {"flops", "bytes_accessed"}
    if prof.captures:                           # backend supports it
        assert costs["flops"] is not None and costs["flops"] > 0
    # detached again: further dispatches must not touch the profiler
    eng.route_many_batch(prefs, sigs)
    assert len(prof.profile()) == 1


def test_trace_capture_degrades_gracefully(tmp_path):
    with trace_capture(None):                   # falsy: pure no-op
        x = 1
    with trace_capture(str(tmp_path / "jx")):   # best-effort profiler
        x += 1
    assert x == 2
