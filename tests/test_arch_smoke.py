"""Per-architecture reduced smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the SMOKE
variant (2 layers, d_model<=256, <=4 experts), run one forward and one
train step on CPU, assert output shapes and no NaNs; then check the
serving invariant decode(prefill(x[:-1]))(x[-1]) == forward(x)[-1].
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import model as M
from repro.training.optimizer import init_opt_state
from repro.training.steps import make_train_step

RNG = np.random.default_rng(0)


def batch_for(cfg, B=2, Lt=24, labels=True):
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, Lt)),
                               jnp.int32)}
    if labels:
        b["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, Lt)),
                                  jnp.int32)
    if cfg.is_encdec:
        b["src_embeds"] = jnp.asarray(
            RNG.standard_normal((B, 12, cfg.frontend_dim)), jnp.float32)
    elif cfg.frontend:
        b["frontend"] = jnp.asarray(
            RNG.standard_normal((B, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.n_params() > 0
    assert cfg.source, "config must cite its source"
    smoke = get_smoke(arch)
    assert smoke.n_layers <= 2 and smoke.d_model <= 512
    assert smoke.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # analytic parameter count must match the actual init
    n_actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert n_actual == cfg.n_params(), (arch, n_actual, cfg.n_params())
    b = batch_for(cfg)
    logits, aux, _ = M.forward_full(params, cfg, b)
    B, Lt = b["tokens"].shape
    Ltot = Lt + (cfg.frontend_tokens if cfg.frontend and not cfg.is_encdec
                 else 0)
    assert logits.shape == (B, Ltot, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg))
    b = batch_for(cfg)
    params2, opt2, metrics = step(params, opt, b)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["gnorm"])), arch
    # params actually moved
    delta = jax.tree_util.tree_map(
        lambda a, c: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - c.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(delta)) > 0, arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_prefill_equals_forward(arch):
    """Cache invariant: decoding the last token against a prefill cache
    of the first L-1 tokens reproduces the full-forward last logits."""
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    b = batch_for(cfg, labels=False)
    logits, _, _ = M.forward_full(params, cfg, b)
    b_head = dict(b)
    b_head["tokens"] = b["tokens"][:, :-1]
    _, cache, pos = M.prefill(params, cfg, b_head)
    dl, _ = M.decode_step(params, cfg, cache,
                          {"token": b["tokens"][:, -1:], "pos": pos})
    err = float(jnp.max(jnp.abs(dl - logits[:, -1])))
    assert err < 5e-2, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "hymba-1.5b",
                                  "h2o-danube-3-4b", "llama3.2-1b"])
def test_multi_step_decode_matches_forward(arch):
    """Generate 4 steps by decode and compare against teacher-forced
    full forwards at every step."""
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    _, cache, pos = M.prefill(params, cfg, {"tokens": toks}, max_len=20)
    for step in range(4):
        nxt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)
        dl, cache = M.decode_step(params, cfg, cache,
                                  {"token": nxt, "pos": pos})
        pos = pos + 1
        toks = jnp.concatenate([toks, nxt], axis=1)
        fl, _, _ = M.forward_full(params, cfg, {"tokens": toks})
        err = float(jnp.max(jnp.abs(dl - fl[:, -1])))
        assert err < 5e-2, f"{arch} step {step}: {err}"
