"""Durable router state: atomic versioned snapshots of bandit
statistics, feedback biases, load EWMAs, and cache contents — restored
bit-exactly into a fresh engine (identical route_many output)."""
import numpy as np
import pytest

from repro.adaptive import LinearBandit
from repro.cache import SemanticCache
from repro.checkpoint import (RouterState, load_router_state,
                              save_router_state)
from repro.core.feedback import FeedbackStore
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import LoadTracker
from tests.test_routing_batch import (StubAnalyzer, random_catalog,
                                      random_queries)

N = 12


def _build(with_all=True):
    m = random_catalog(N, seed=4)
    kw = {}
    if with_all:
        kw = dict(adaptive=LinearBandit(N, seed=1), adaptive_weight=0.7,
                  load=LoadTracker(N, capacity=2.0), load_weight=0.8,
                  cache=SemanticCache(capacity=32, sketch_dims=16,
                                      min_quality=0.2))
    return OptiRoute(m, StubAnalyzer(), feedback=FeedbackStore(), **kw)


def _warm(router):
    """Accumulate non-trivial learned state in every component."""
    eng = ServingEngine(router)
    reqs = [Request(text=f"q {i % 4} words here", prefs="balanced", id=i)
            for i in range(8)]
    out = eng.submit(reqs)
    eng.observe(out, list(np.linspace(0.3, 0.9, 8)))
    router.give_feedback(out[0].rq, True)
    router.give_feedback(out[1].rq, False)
    if router.load is not None:
        router.load.admit_many(np.array([0, 0, 3, 5]))
        router.load.start(0)
        router.load.finish(0, 0.123)
    return out


def test_round_trip_bit_exact_routing(tmp_path):
    r1 = _build()
    _warm(r1)
    prefs, sigs = random_queries(6, seed=7)
    before = r1.engine.route_many(prefs, sigs)

    state = RouterState(str(tmp_path))
    state.save(r1, step=5)
    r2 = _build()
    assert state.restore(r2) == 5

    # every component restored bit-exactly
    np.testing.assert_array_equal(r1.adaptive.A, r2.adaptive.A)
    np.testing.assert_array_equal(r1.adaptive.b, r2.adaptive.b)
    np.testing.assert_array_equal(r1.adaptive.counts, r2.adaptive.counts)
    assert r1.feedback.state() == r2.feedback.state()
    for a, b in zip(r1.load.state().values(), r2.load.state().values()):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(r1.cache.vecs, r2.cache.vecs)
    np.testing.assert_array_equal(r1.cache.valid, r2.cache.valid)

    # the acceptance criterion: identical route_many output
    after = r2.engine.route_many(prefs, sigs)
    for a, b in zip(before, after):
        assert a.model == b.model
        assert a.score == b.score                # bit-exact, no approx
        assert a.candidates == b.candidates
        assert a.fallback_kind == b.fallback_kind


def test_restored_cache_answers_warm(tmp_path):
    r1 = _build()
    out = _warm(r1)
    state = RouterState(str(tmp_path))
    state.save(r1, step=1)
    r2 = _build()
    state.restore(r2)
    eng2 = ServingEngine(r2)
    reqs = [Request(text=f"q {i % 4} words here", prefs="balanced", id=i)
            for i in range(8)]
    out2 = eng2.submit(reqs)
    hits = [r for r in out2 if r.cache_hit]
    assert hits, "restored cache must answer the replayed head"
    stored_models = {e for e, ok in zip(r1.cache.models, r1.cache.valid)
                     if ok}
    assert {r.model for r in hits} <= stored_models


def test_single_file_variant(tmp_path):
    r1 = _build()
    _warm(r1)
    path = str(tmp_path / "router.npz")
    save_router_state(path, r1)
    r2 = _build()
    meta = load_router_state(path, r2)
    assert meta["router_state_version"] == 1
    assert sorted(meta["components"]) == ["bandit", "cache", "feedback",
                                          "load"]
    prefs, sigs = random_queries(4, seed=2)
    for a, b in zip(r1.engine.route_many(prefs, sigs),
                    r2.engine.route_many(prefs, sigs)):
        assert a.model == b.model and a.score == b.score


def test_cold_start_and_retention(tmp_path):
    state = RouterState(str(tmp_path / "empty"))
    assert state.restore(_build()) is None       # cold start: no-op
    r = _build()
    _warm(r)
    state2 = RouterState(str(tmp_path / "steps"), keep=2)
    for step in (1, 2, 3, 4):
        state2.save(r, step=step)
    assert state2.mgr.steps() == [3, 4]          # retention pruned 1, 2
    assert state2.restore(_build()) == 4


def test_feedback_only_router_round_trips(tmp_path):
    """Components the router does not carry are skipped cleanly."""
    r1 = _build(with_all=False)
    r1.feedback.record(TaskSignature(), "m3", True)
    path = str(tmp_path / "fb.npz")
    save_router_state(path, r1)
    r2 = _build(with_all=False)
    meta = load_router_state(path, r2)
    assert meta["components"] == ["feedback"]
    assert r1.feedback.state() == r2.feedback.state()


def test_restore_into_missing_component_raises(tmp_path):
    r1 = _build()
    _warm(r1)
    path = str(tmp_path / "full.npz")
    save_router_state(path, r1)
    r2 = _build(with_all=False)                  # no bandit/load/cache
    with pytest.raises(ValueError, match="no such component"):
        load_router_state(path, r2)


def test_empty_feedback_round_trips(tmp_path):
    """Zero-entry components must not corrupt the npz round-trip."""
    r1 = _build()
    path = str(tmp_path / "empty.npz")
    save_router_state(path, r1)                  # nothing learned yet
    r2 = _build()
    load_router_state(path, r2)
    assert r2.feedback.state() == []
    assert len(r2.cache) == 0
