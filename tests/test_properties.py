"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.feedback import FeedbackStore
from repro.core.mres import MRES, normalize_catalog
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences)
from repro.core.routing import FALLBACK_LADDER, RoutingEngine
from tests.conftest import make_entry

FAST = settings(max_examples=40, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ----------------------------------------------------------------------
# strategies
# ----------------------------------------------------------------------

@st.composite
def catalogs(draw, min_n=2, max_n=10):
    n = draw(st.integers(min_n, max_n))
    m = MRES()
    for i in range(n):
        tts = draw(st.sets(st.sampled_from(TASK_TYPES), min_size=1,
                           max_size=4))
        dms = draw(st.sets(st.sampled_from(DOMAINS), min_size=1, max_size=3))
        m.register(make_entry(
            f"m{i}",
            accuracy=draw(st.floats(0, 1)),
            latency_ms=draw(st.floats(1, 1000)),
            cost=draw(st.floats(0.01, 100)),
            helpfulness=draw(st.floats(0, 1)),
            harmlessness=draw(st.floats(0, 1)),
            honesty=draw(st.floats(0, 1)),
            task_types=tuple(tts), domains=tuple(dms),
            generalist=draw(st.booleans())))
    return m


@st.composite
def signatures(draw):
    return TaskSignature(
        task_type=draw(st.sampled_from(TASK_TYPES)),
        domain=draw(st.sampled_from(DOMAINS)),
        complexity=draw(st.floats(0, 1)),
        confidence=draw(st.floats(0, 1)))


@st.composite
def preferences(draw):
    w = {m: draw(st.floats(0, 1)) for m in METRICS}
    return UserPreferences(weights=w)


# ----------------------------------------------------------------------
# invariants
# ----------------------------------------------------------------------

@FAST
@given(catalogs(), signatures(), preferences())
def test_route_always_returns_a_model(mres, sig, prefs):
    """(iv) fallback totality: routing never fails on a non-empty catalog."""
    d = RoutingEngine(mres).route(prefs, sig)
    assert d.model in {e.name for e in mres.entries}
    assert np.isfinite(d.score)


@FAST
@given(catalogs(), signatures(), preferences())
def test_chosen_model_passes_hard_filters_when_any_does(mres, sig, prefs):
    """(i) if any model passes both filters, the chosen one does too."""
    eng = RoutingEngine(mres, confidence_threshold=0.0)
    d = eng.route(prefs, sig)
    entry = mres.entry(d.model)
    any_pass = any(sig.task_type in e.task_types and sig.domain in e.domains
                   for e in mres.entries)
    if any_pass:
        assert sig.task_type in entry.task_types
        assert sig.domain in entry.domains


@FAST
@given(catalogs(), signatures(), preferences(),
       st.sampled_from(METRICS), st.floats(0.1, 1.0))
def test_weight_monotonicity(mres, sig, prefs, metric, bump):
    """(ii) raising the weight of a metric never worsens the chosen
    model's normalized value on that metric."""
    eng = RoutingEngine(mres, confidence_threshold=0.0)
    emb = mres.embeddings()
    names = [e.name for e in mres.entries]
    ax = METRICS.index(metric)
    d1 = eng.route(prefs, sig)
    hi = prefs.with_weight(metric, min(1.0, prefs.weights.get(metric, 0.25)
                                       + bump))
    d2 = eng.route(hi, sig)
    v1 = emb[names.index(d1.model), ax]
    v2 = emb[names.index(d2.model), ax]
    assert v2 >= v1 - 1e-6


@FAST
@given(st.lists(st.tuples(st.floats(0.001, 1e6), st.floats(0.001, 1e6)),
                min_size=1, max_size=12),
       st.floats(0.01, 1000))
def test_normalization_bounds_and_scale_invariance(rows, scale):
    """(iii) normalization maps into [0,1] and is scale-invariant."""
    entries = [make_entry(f"m{i}", accuracy=a, latency_ms=l)
               for i, (a, l) in enumerate(rows)]
    e1 = normalize_catalog(entries)
    assert (e1 >= 0).all() and (e1 <= 1).all()
    scaled = [make_entry(f"m{i}", accuracy=a * scale, latency_ms=l * scale)
              for i, (a, l) in enumerate(rows)]
    e2 = normalize_catalog(scaled)
    np.testing.assert_allclose(e1, e2, rtol=1e-5, atol=1e-7)


@FAST
@given(st.lists(st.booleans(), min_size=1, max_size=200),
       st.floats(0.05, 0.95))
def test_feedback_bias_bounded(thumbs, alpha):
    """(v) feedback EMA stays in [-1, 1] under any thumb sequence."""
    fs = FeedbackStore(alpha=alpha)
    sig = TaskSignature()
    for t in thumbs:
        b = fs.record(sig, "m", t)
        assert -1.0 <= b <= 1.0
    assert abs(fs.bias(sig, ["m"])[0]) <= 1.0


@FAST
@given(signatures())
def test_task_vector_in_unit_box(sig):
    eng = RoutingEngine.__new__(RoutingEngine)
    prefs = UserPreferences(weights={m: 1.0 for m in METRICS})
    v = eng.task_vector(prefs, sig)
    assert (v >= 0).all() and (v <= 1).all()


# ----------------------------------------------------------------------
# fallback-ladder invariants
# ----------------------------------------------------------------------

def _ladder_masks(mres, eng, sig):
    """The staged candidate masks exactly as route_many builds them."""
    conf = sig.confidence >= eng.confidence_threshold
    tt, dm = mres.masks(sig.task_type if conf else None,
                        sig.domain if conf else None)
    n = len(mres.entries)
    return [("", tt & dm), ("widened-knn", tt & dm),
            ("task-type-only", tt),
            ("generalist", mres.generalist_mask().copy()),
            ("any", np.ones(n, bool))]


@FAST
@given(catalogs(), signatures(), preferences())
def test_fallback_stage_mask_invariant(mres, sig, prefs):
    """(vi) the chosen model always satisfies the FIRST non-empty
    ladder stage's mask, and the reported stage label is consistent
    with that rung (labels drawn from FALLBACK_LADDER)."""
    eng = RoutingEngine(mres)
    d = eng.route(prefs, sig)
    assert d.fallback_kind in FALLBACK_LADDER
    assert d.used_fallback == (d.fallback_kind != "")
    names = [e.name for e in mres.entries]
    stages = _ladder_masks(mres, eng, sig)
    fi = next(i for i, (_, m) in enumerate(stages) if m.any())
    assert stages[fi][1][names.index(d.model)]
    # primary and widened-kNN share a mask, so either label is a valid
    # report for rung 0; deeper rungs must report their own label
    allowed = {"", "widened-knn"} if fi == 0 else {stages[fi][0]}
    assert d.fallback_kind in allowed
    # label/mask consistency: the model passes its REPORTED stage too
    label_mask = dict(stages)[d.fallback_kind]
    assert label_mask[names.index(d.model)]


@st.composite
def query_batches(draw, max_b=5):
    b = draw(st.integers(1, max_b))
    return ([draw(preferences()) for _ in range(b)],
            [draw(signatures()) for _ in range(b)])


@FAST
@given(catalogs(), query_batches())
def test_route_many_equals_single_route(mres, batch):
    """(vii) single-vs-batch differential: route(p, s) is decision-
    identical to route_many over any batch containing (p, s)."""
    prefs, sigs = batch
    eng = RoutingEngine(mres)
    out = eng.route_many(prefs, sigs)
    assert len(out) == len(sigs)
    for d_b, p, s in zip(out, prefs, sigs):
        d_1 = eng.route(p, s)
        assert d_b.model == d_1.model
        assert d_b.fallback_kind == d_1.fallback_kind
        assert d_b.score == pytest.approx(d_1.score, abs=1e-5)
        assert [n for n, _ in d_b.candidates] == \
            [n for n, _ in d_1.candidates]


# ----------------------------------------------------------------------
# fused single-dispatch route step vs the staged reference path
# ----------------------------------------------------------------------

@st.composite
def blend_layers(draw, n_models):
    """Optional feedback / bandit / load layers with random state and
    weights (None = layer off)."""
    from repro.adaptive.bandit import LinearBandit
    from repro.serving.load import LoadTracker
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    fb = None
    if draw(st.booleans()):
        fb = FeedbackStore()
        for _ in range(draw(st.integers(1, 25))):
            fb.record(TaskSignature(
                task_type=str(rng.choice(TASK_TYPES)),
                domain=str(rng.choice(DOMAINS)),
                complexity=float(rng.random())),
                f"m{int(rng.integers(n_models))}",
                bool(rng.random() < 0.5))
    ad = None
    ad_w = 0.0
    if draw(st.booleans()):
        ad = LinearBandit(n_models, seed=int(rng.integers(2**31)))
        X = rng.random((12, len(METRICS))).astype(np.float32)
        ad.update(X, rng.integers(0, n_models, 12),
                  rng.random(12).astype(np.float32))
        ad_w = draw(st.floats(0.1, 2.0))
    load = None
    load_w = 0.0
    if draw(st.booleans()):
        load = LoadTracker(n_models)
        for j in rng.integers(0, n_models, 4 * n_models):
            load.admit(int(j))
        load_w = draw(st.floats(0.1, 2.0))
    return fb, ad, ad_w, load, load_w


def _knn_is_tie_free(mres, eng, sig, tvec, tol=1e-5) -> bool:
    """True when the query's mask-fused cosine values are pairwise
    distinct by > tol — only then is the kNN candidate SET uniquely
    determined, and the fused/staged backends comparable strictly.
    (With exact ties — e.g. duplicate catalog rows — the candidate
    choice is legitimately backend-defined.)"""
    from repro.core.routing import cosine_sim
    emb = mres.embeddings()
    conf = sig.confidence >= eng.confidence_threshold
    ttm, dmm = mres.masks(sig.task_type if conf else None,
                          sig.domain if conf else None)
    vals = np.sort(cosine_sim(emb, tvec)[ttm & dmm])
    return vals.size < 2 or np.min(np.diff(vals)) > tol


@FAST
@given(catalogs(max_n=14), query_batches(max_b=6),
       st.data())
def test_fused_route_step_equals_staged_path(mres, batch, data):
    """(viii) fused-vs-staged differential: the single-dispatch fused
    ``route_many_batch`` (one jitted device program: kNN + feedback +
    bandit + load blend + candidate argmax + in-program fallback
    ladder) matches the staged numpy reference on model choice,
    fallback stage, stage sizes and (to fp tolerance) scores — across
    random catalogs, masks, blend weights, and B=1 vs batched."""
    prefs, sigs = batch
    fb, ad, ad_w, load, load_w = data.draw(
        blend_layers(len(mres.entries)))
    eng = RoutingEngine(mres, fb, knn_k=4,
                        adaptive=ad, adaptive_weight=ad_w,
                        load=load, load_weight=load_w)
    fused = eng.route_many_batch(prefs, sigs).decisions()
    staged = eng.route_many_staged(prefs, sigs)
    b1 = [eng.route_many_batch([p], [s]).decision(0)
          for p, s in zip(prefs, sigs)]
    for a, b, c, sig in zip(fused, staged, b1, sigs):
        # structural facts are backend-independent, ties or not
        assert a.fallback_kind == b.fallback_kind == c.fallback_kind
        assert a.stage_sizes == b.stage_sizes == c.stage_sizes
        assert len(a.candidates) == len(b.candidates)
        if not _knn_is_tie_free(mres, eng, sig, b.task_vector):
            continue        # candidate set not uniquely determined
        assert a.score == pytest.approx(b.score, abs=1e-4)
        assert c.score == pytest.approx(b.score, abs=1e-4)
        if a.model != b.model or c.model != b.model:
            # fp tie at the top of the blend: both picks must score
            # within tolerance of the staged best
            a_in_b = dict(b.candidates).get(a.model)
            assert a_in_b is not None
            assert a_in_b == pytest.approx(b.score, abs=1e-4)
        for (_, sa), (_, sb) in zip(a.candidates, b.candidates):
            assert sa == pytest.approx(sb, abs=1e-4)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(catalogs(max_n=12), query_batches(max_b=5), st.data())
def test_sharded_fused_route_step_equals_staged_path(mres, batch, data):
    """(ix) catalog-sharded differential: the cross-device fused step
    (catalog axis sharded over the multi-device host mesh, per-shard
    mask-fused kNN + payload-carrying cross-shard merge tree) matches
    the staged numpy reference — model choice, the full in-program
    fallback ladder, stage sizes, and scores to fp tolerance — across
    random catalogs, masks, and blend layers."""
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs a multi-device host platform")
    from repro.launch.mesh import make_routing_mesh
    prefs, sigs = batch
    fb, ad, ad_w, load, load_w = data.draw(
        blend_layers(len(mres.entries)))
    eng = RoutingEngine(mres, fb, knn_k=4,
                        adaptive=ad, adaptive_weight=ad_w,
                        load=load, load_weight=load_w,
                        mesh=make_routing_mesh())
    fused = eng.route_many_batch(prefs, sigs).decisions()
    staged = eng.route_many_staged(prefs, sigs)
    for a, b, sig in zip(fused, staged, sigs):
        assert a.fallback_kind == b.fallback_kind
        assert a.stage_sizes == b.stage_sizes
        assert len(a.candidates) == len(b.candidates)
        if not _knn_is_tie_free(mres, eng, sig, b.task_vector):
            continue        # candidate set not uniquely determined
        assert a.score == pytest.approx(b.score, abs=1e-4)
        if a.model != b.model:
            a_in_b = dict(b.candidates).get(a.model)
            assert a_in_b is not None
            assert a_in_b == pytest.approx(b.score, abs=1e-4)
        for (_, sa), (_, sb) in zip(a.candidates, b.candidates):
            assert sa == pytest.approx(sb, abs=1e-4)


# ----------------------------------------------------------------------
# fused analyze->route: tokens->decision program == staged pipeline
# ----------------------------------------------------------------------

def _tiny_analyzer():
    """One shared tiny analyzer (module-cached): the property varies
    the TEXTS and CATALOGS, not the weights, so a single jit bucket
    serves every example."""
    global _TINY_ANALYZER
    if _TINY_ANALYZER is None:
        from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
        _TINY_ANALYZER = TaskAnalyzer(
            AnalyzerConfig(vocab_size=256, d_model=16, n_layers=1,
                           n_heads=2, d_ff=32, max_len=12), seed=5)
    return _TINY_ANALYZER


_TINY_ANALYZER = None

texts_st = st.lists(st.text(alphabet="abcdefgh ", min_size=0,
                            max_size=40), min_size=1, max_size=10)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(catalogs(max_n=10), texts_st, st.floats(0.0, 1.0))
def test_fused_analyze_route_equals_staged_pipeline(mres, texts,
                                                    threshold):
    """(x) tokens->decision differential: the single fused device
    program (analyzer forward + heads + task-vector build + route
    blend in one dispatch) matches the staged analyze_batch ->
    route_many pipeline — same signatures, fallback kinds, and scores;
    same model whenever the candidate field is tie-free."""
    an = _tiny_analyzer()
    eng = RoutingEngine(mres, knn_k=4, confidence_threshold=threshold)
    toks = an.encode_batch(texts)
    batch = eng.route_tokens_batch(an.params, an.cfg, toks, "balanced")
    sigs = an.analyze_batch(texts)
    staged = eng.route_many("balanced", sigs)
    for i, (sig, d) in enumerate(zip(sigs, staged)):
        got = batch.signature(i)
        assert (got.task_type, got.domain) == (sig.task_type,
                                               sig.domain)
        assert got.complexity == pytest.approx(sig.complexity,
                                               abs=1e-5)
        assert batch.fallback_kind(i) == d.fallback_kind
        assert batch.score[i] == pytest.approx(d.score, abs=1e-4)
        if batch.models()[i] != d.model:
            # legitimate only under an exact near-tie: the fused pick
            # must appear among the staged candidates at the top score
            near = dict(d.candidates).get(batch.models()[i])
            assert near is not None
            assert near == pytest.approx(d.score, abs=1e-4)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.text(alphabet="abc XYZ'!2.", min_size=0,
                        max_size=60), min_size=0, max_size=10),
       st.integers(1, 24))
def test_encode_batch_matches_encode(texts, max_len):
    """(xi) vectorized ``encode_batch`` is bit-identical to the
    per-row reference ``encode`` loop for arbitrary text/max_len."""
    from repro.data.tokenizer import PAD_ID, HashTokenizer
    tok = HashTokenizer(128)
    got = tok.encode_batch(texts, max_len)
    want = np.full((len(texts), max_len), PAD_ID, np.int32)
    for i, t in enumerate(texts):
        ids = tok.encode(t, max_len)
        want[i, :len(ids)] = ids
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(0, 300), min_size=1, max_size=6),
       st.integers(0, 2 ** 16))
def test_prune_texts_matches_prune_text(lengths, seed):
    """(xii) batch pruning == per-text reference pruning across the
    budget boundary (same per-text rng stream, same kept indices)."""
    from repro.core.analyzer import (AnalyzerConfig, prune_text,
                                     prune_texts)
    cfg = AnalyzerConfig(prune_head=10, prune_tail=6, prune_mid=4)
    texts = [" ".join(f"w{i}" for i in range(n)) for n in lengths]
    assert prune_texts(cfg, texts, seed=seed) == \
        [prune_text(cfg, t, seed=seed) for t in texts]
