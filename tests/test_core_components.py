"""Unit tests: feedback store, merging, checkpointing, tokenizer,
analyzer pruning/quantization."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, load, save
from repro.core.analyzer import (AnalyzerConfig, TaskAnalyzer, init_analyzer,
                                 analyzer_forward, prune_text, quantize_int8)
from repro.core.feedback import FeedbackStore, cluster_of
from repro.core.merging import ModelMerger, merged_metrics, soup
from repro.core.mres import MRES
from repro.core.preferences import TaskSignature, UserPreferences
from repro.data.tokenizer import BOS_ID, PAD_ID, HashTokenizer
from repro.data.workload import make_workload
from tests.conftest import make_entry


# ----------------------------------------------------------------------
# feedback
# ----------------------------------------------------------------------

def test_feedback_ema_direction():
    fs = FeedbackStore(alpha=0.5)
    sig = TaskSignature(task_type="code", domain="software", complexity=0.7)
    assert fs.record(sig, "m", True) > 0
    after_ups = fs.record(sig, "m", True)
    assert after_ups > 0.5
    after_down = fs.record(sig, "m", False)
    assert after_down < after_ups             # thumbs-down lowers the bias
    np.testing.assert_allclose(fs.bias(sig, ["m"])[0], after_down)


def test_feedback_cluster_granularity():
    a = TaskSignature(task_type="code", domain="software", complexity=0.1)
    b = TaskSignature(task_type="code", domain="software", complexity=0.9)
    fs = FeedbackStore()
    fs.record(a, "m", False)
    assert cluster_of(a) != cluster_of(b)
    assert fs.bias(b, ["m"])[0] == 0.0        # different bucket untouched


def test_feedback_persistence(tmp_path):
    fs = FeedbackStore()
    sig = TaskSignature()
    fs.record(sig, "m", True)
    p = str(tmp_path / "fb.json")
    fs.save(p)
    fs2 = FeedbackStore()
    fs2.load(p)
    np.testing.assert_allclose(fs2.bias(sig, ["m"]), fs.bias(sig, ["m"]))


# ----------------------------------------------------------------------
# merging
# ----------------------------------------------------------------------

def test_soup_is_weighted_average():
    t1 = {"a": jnp.ones((2, 2)), "b": [jnp.zeros(3)]}
    t2 = {"a": jnp.zeros((2, 2)), "b": [jnp.ones(3)]}
    s = soup([t1, t2], [0.25, 0.75])
    np.testing.assert_allclose(np.asarray(s["a"]), 0.25)
    np.testing.assert_allclose(np.asarray(s["b"][0]), 0.75)


def test_merger_creates_entry_when_profitable():
    m = MRES()
    # two same-family models: one accurate+slow, one fast+weak
    m.register(make_entry("acc", accuracy=0.9, latency_ms=500, cost=10,
                          family="dense", n_params=100))
    m.register(make_entry("fast", accuracy=0.3, latency_ms=5, cost=0.1,
                          family="dense", n_params=100))
    merger = ModelMerger(m)
    prefs = UserPreferences(weights={"accuracy": 1.0, "speed": 1.0,
                                     "cheapness": 1.0})
    sig = TaskSignature()
    e = merger.maybe_merge(prefs, sig, incumbent_score=0.0)
    assert e is not None and e.name.startswith("soup:")
    assert len(m) == 3
    # merged metrics interpolate the parents
    mm = merged_metrics([m.entry("acc"), m.entry("fast")], [0.5, 0.5])
    assert mm["accuracy"] == pytest.approx(0.6)
    assert mm["latency_ms"] == pytest.approx(252.5)


def test_merger_respects_family_boundary():
    m = MRES()
    m.register(make_entry("a", family="dense", n_params=10))
    m.register(make_entry("b", family="moe", n_params=10))
    assert ModelMerger(m).candidate_pairs() == []


def test_runner_soup_changes_output():
    from repro.configs import get_smoke
    from repro.serving.runner import ModelRunner
    cfg = get_smoke("llama3.2-1b")
    r1 = ModelRunner(cfg, seed=0)
    r2 = ModelRunner(cfg, seed=1)
    merged = r1.merged_with(r2, 0.5)
    toks = np.arange(8, dtype=np.int32)[None] + 2
    g1 = r1.generate(toks, max_new=2)
    gm = merged.generate(toks, max_new=2)
    assert g1.logits_last.shape == gm.logits_last.shape
    assert not np.allclose(g1.logits_last, gm.logits_last)


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": np.arange(6, np.float32()).reshape(2, 3)
            if False else np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones(4, np.int32)},
            "stack": [np.zeros(2), np.full(2, 7.0)]}
    p = str(tmp_path / "x.npz")
    save(p, tree, {"note": "hi"})
    got, meta = load(p)
    assert meta["note"] == "hi"
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])
    np.testing.assert_array_equal(got["stack"][1], tree["stack"][1])
    assert isinstance(got["stack"], list)


def test_checkpoint_manager_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"x": np.full(1, s)})
    assert cm.steps() == [3, 4]
    step, tree, meta = cm.restore_latest()
    assert step == 4 and float(tree["x"][0]) == 4.0


# ----------------------------------------------------------------------
# tokenizer
# ----------------------------------------------------------------------

def test_tokenizer_deterministic_and_padded():
    tok = HashTokenizer(512)
    a = tok.encode("Hello World hello")
    b = tok.encode("hello world HELLO")
    assert a == b and a[0] == BOS_ID
    assert a[1] == a[3]                       # same word -> same id
    batch = tok.encode_batch(["one two", "three"], max_len=6)
    assert batch.shape == (2, 6)
    assert (batch[0, 3:] == PAD_ID).all()
    assert (batch >= 0).all() and (batch < 512).all()


# ----------------------------------------------------------------------
# analyzer (pruning + quantization; training covered by integration)
# ----------------------------------------------------------------------

def test_prune_preserves_edges_and_budget():
    cfg = AnalyzerConfig(prune_head=10, prune_tail=5, prune_mid=3)
    words = [f"w{i}" for i in range(200)]
    out = prune_text(cfg, " ".join(words)).split()
    assert len(out) == 18
    assert out[:10] == words[:10]
    assert out[-5:] == words[-5:]
    short = "just a short query"
    assert prune_text(cfg, short) == short


def test_prune_deterministic():
    cfg = AnalyzerConfig()
    text = " ".join(f"w{i}" for i in range(500))
    assert prune_text(cfg, text, seed=3) == prune_text(cfg, text, seed=3)


def test_int8_quantization_close_logits():
    cfg = AnalyzerConfig(d_model=32, n_layers=1, d_ff=64, max_len=16)
    params = init_analyzer(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        2, cfg.vocab_size, (3, 16)), jnp.int32)
    tt1, dm1, cx1 = analyzer_forward(params, cfg, toks)
    qp = quantize_int8(params)
    # every 2-D matrix became (int8, scale)
    assert isinstance(qp["head_tt"], tuple)
    assert qp["head_tt"][0].dtype == jnp.int8
    tt2, dm2, cx2 = analyzer_forward(qp, cfg, toks)
    assert np.argmax(np.asarray(tt1), 1).tolist() == \
        np.argmax(np.asarray(tt2), 1).tolist() or \
        np.max(np.abs(np.asarray(tt1) - np.asarray(tt2))) < 0.5


def test_workload_ground_truth_consistent():
    recs = make_workload(50, seed=0)
    assert len({r.text for r in recs}) > 40      # diverse
    for r in recs:
        r.sig.validate()
    again = make_workload(50, seed=0)
    assert [r.text for r in again] == [r.text for r in recs]
