"""Sharding rules coherence on a small host-side mesh.

The full 256/512-chip lowering is proven by the dry-run sweep
(results/dryrun/*.json, EXPERIMENTS.md §Dry-run); these tests check the
rule layer itself: spec trees match param trees, divisibility handling,
and an actual pjit run on a tiny (1,1) mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import rules as R


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_cover_tree(arch):
    cfg = get_config(arch)
    mesh = make_host_mesh()
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    specs = R.param_specs(cfg, mesh, shapes)
    flat_s, tdef_s = jax.tree_util.tree_flatten(specs)
    flat_p, tdef_p = jax.tree_util.tree_flatten(shapes)
    assert tdef_s == tdef_p
    for spec, leaf in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape)


def test_divisibility_drives_sharding():
    from repro.launch import dryrun  # noqa: F401 — not imported here; use mesh math
    cfg = get_config("qwen2-1.5b")
    mesh = make_host_mesh()            # axes sizes 1 -> everything "shards"
    assert R.maybe(mesh, 10, "model") == "model"   # 10 % 1 == 0
    assert R.axis_size(mesh, ("data", "model")) == 1
    assert R.axis_size(mesh, None) == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_model_dims_divisible_by_16(arch):
    """DESIGN.md §5 claim: all sharded dims divide the 16-way model axis."""
    cfg = get_config(arch)
    assert cfg.vocab_padded % 16 == 0
    assert cfg.d_model % 16 == 0
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0 or cfg.is_moe
    if cfg.has_attention:
        assert cfg.q_dim % 16 == 0
    if cfg.is_moe:
        assert cfg.n_experts % 16 == 0


def test_pjit_train_step_on_host_mesh():
    """Full pjit path (specs -> jit -> run) on the 1-device mesh."""
    from repro.training.optimizer import init_opt_state
    from repro.training.steps import make_train_step
    cfg = get_smoke("llama3.2-1b")
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    set_mesh = getattr(jax, "set_mesh", None)
    # jax < 0.5 has no jax.set_mesh and jit only accepts Shardings (not
    # bare PartitionSpecs): wrap specs in NamedSharding there.
    wrap = ((lambda t: t) if set_mesh is not None else
            (lambda t: jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), t,
                is_leaf=lambda x: isinstance(x, P))))
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        pspecs = R.param_specs(cfg, mesh, params)
        opt = init_opt_state(params)
        ospecs = {"mu": pspecs, "nu": pspecs, "step": P()}
        batch = {"tokens": jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 64, (2, 16)), jnp.int32)}
        bspecs = R.batch_spec(cfg, mesh, batch)
        step = jax.jit(make_train_step(cfg),
                       in_shardings=(wrap(pspecs), wrap(ospecs),
                                     wrap(bspecs)))
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_cache_specs_long_context_shards_length():
    """batch=1 decode shards the cache length axis over data (DESIGN §5)."""
    cfg = get_config("mamba2-1.3b")
    mesh = make_host_mesh()
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 1, 1024))
    specs = R.cache_specs(cfg, mesh, cache)
    assert "ssd" in specs and isinstance(specs["ssd"], P)
    cfg2 = get_config("h2o-danube-3-4b")
    cache2 = jax.eval_shape(lambda: M.init_cache(cfg2, 1, 4096))
    specs2 = R.cache_specs(cfg2, mesh, cache2)
    # KV cache present and spec'd per (k, v)
    assert set(specs2) >= {"k", "v"}


def test_maybe_counts_silent_replications():
    """Every ``maybe`` fallback to replication (non-dividing dim) bumps
    the audit counter the dry-run surfaces — divisible dims don't."""
    mesh = make_host_mesh()                      # both axes size 1
    base = R.silent_replication_count()
    assert R.maybe(mesh, 10, "model") == "model"
    assert R.silent_replication_count() == base  # clean shard: no bump
    mesh4 = jax.make_mesh((4,), ("data",))
    assert R.maybe(mesh4, 8, "data") == "data"
    assert R.silent_replication_count() == base
    assert R.maybe(mesh4, 6, "data") is None     # 6 % 4 != 0: replicate
    assert R.maybe(mesh4, 1, "data") is None
    assert R.silent_replication_count() == base + 2
    R.reset_silent_replication_count()
    assert R.silent_replication_count() == 0


def test_route_step_specs_cover_catalog_axis():
    """The mega-catalog routing specs shard every (.., N) operand over
    the catalog axis and replicate the per-query operands."""
    mesh = jax.make_mesh((4,), (R.CATALOG_AXIS,))
    specs = R.route_step_specs(mesh)
    assert specs["e2"] == P(R.CATALOG_AXIS, None)
    assert specs["masks_table"] == P(None, R.CATALOG_AXIS)
    assert specs["lpen"] == P(R.CATALOG_AXIS)
    assert specs["counts_table"] == P()
    assert specs["query"] == P()
    with pytest.raises(AssertionError):
        R.route_step_specs(make_host_mesh())     # no catalog axis
